//! CLI integration: drive the `tamio` binary end-to-end (arg parsing,
//! config files, subcommands, exit codes).

use std::process::Command;

fn tamio() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tamio"))
}

#[test]
fn help_lists_subcommands() {
    let out = tamio().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["run", "sweep", "scaling", "table1", "congest", "info"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn run_with_verify_succeeds_and_prints_breakdown() {
    let out = tamio()
        .args([
            "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--algorithm", "tam:2", "--stripe_size", "4096", "--stripe_count", "4",
            "--verify",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("io_phase"));
    assert!(text.contains("verify[write]: 8/8 ranks OK"));
}

#[test]
fn run_with_overlap_on_verifies_both_directions() {
    let out = tamio()
        .args([
            "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--algorithm", "tam:2", "--stripe_size", "4096", "--stripe_count", "4",
            "--direction", "both", "--verify", "--overlap", "on",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("overlap=on"), "run header must echo the mode:\n{text}");
    assert!(text.contains("overlap_saved"), "breakdown row missing:\n{text}");
    // Pipelining is a schedule, not a result: bytes still round-trip.
    assert!(text.contains("verify[write]: 8/8 ranks OK"), "{text}");
    assert!(text.contains("verify[read]: 8/8 ranks OK"), "{text}");
}

#[test]
fn garbage_overlap_fails_instead_of_substituting_the_default() {
    let out = tamio()
        .args(["run", "--overlap", "sideways"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "a typo'd overlap mode must not silently default");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("sideways"), "error must quote the bad value: {err}");
    assert!(err.contains("on|off|auto"), "error must list the valid modes: {err}");
}

#[test]
fn info_reports_send_mode_and_overlap() {
    let out = tamio()
        .args(["info", "--send_mode", "isend", "--overlap", "auto"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("worker pool:"), "{text}");
    assert!(text.contains("send_mode: isend"), "{text}");
    assert!(text.contains("overlap: auto"), "{text}");
}

#[test]
fn run_direction_read_verifies_two_phase_and_tam() {
    for algo in ["two-phase", "tam:4"] {
        let out = tamio()
            .args([
                "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
                "--algorithm", algo, "--stripe_size", "4096", "--stripe_count", "4",
                "--direction", "read",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{algo}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("direction=read"), "{algo}:\n{text}");
        // Read runs verify the gathered bytes even without --verify.
        assert!(text.contains("verify[read]: 8/8 ranks OK"), "{algo}:\n{text}");
    }
}

#[test]
fn run_direction_both_prints_write_and_read_verdicts() {
    for algo in ["two-phase", "tam:4"] {
        let out = tamio()
            .args([
                "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
                "--algorithm", algo, "--stripe_size", "4096", "--stripe_count", "4",
                "--direction", "both", "--verify",
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "{algo}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("verify[write]: 8/8 ranks OK"), "{algo}:\n{text}");
        assert!(text.contains("verify[read]: 8/8 ranks OK"), "{algo}:\n{text}");
        assert!(text.contains("[write]") && text.contains("[read]"), "{algo}:\n{text}");
    }
}

#[test]
fn run_tree_algorithm_on_hierarchical_topology_verifies() {
    let out = tamio()
        .args([
            "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--sockets_per_node", "2", "--rank_placement", "block",
            "--algorithm", "tree:socket=1,node=1", "--stripe_size", "4096",
            "--stripe_count", "4", "--direction", "both", "--verify",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tree(socket=1,node=1)"), "{text}");
    assert!(text.contains("verify[write]: 8/8 ranks OK"), "{text}");
    assert!(text.contains("verify[read]: 8/8 ranks OK"), "{text}");
    // Per-level intra rows appear in the breakdown table.
    assert!(text.contains("intra[socket]"), "{text}");
    assert!(text.contains("intra[node]"), "{text}");
}

#[test]
fn run_algorithm_auto_resolves_and_verifies() {
    let out = tamio()
        .args([
            "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--sockets_per_node", "2", "--algorithm", "auto", "--stripe_size", "4096",
            "--stripe_count", "4", "--direction", "both", "--verify",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // The panel labels carry the resolved spec, e.g. "auto[tree(node=2)]".
    assert!(text.contains("auto["), "resolved label missing:\n{text}");
    assert!(text.contains("verify[write]: 8/8 ranks OK"), "{text}");
    assert!(text.contains("verify[read]: 8/8 ranks OK"), "{text}");
}

#[test]
fn sweep_validate_tuner_reports_rank_correlation() {
    let out = tamio()
        .args([
            "sweep", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--algorithm", "auto", "--stripe_size", "4096", "--stripe_count", "4",
            "--validate-tuner",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("-- tuner validation [write] --"), "{text}");
    assert!(text.contains("rank-correlation (spearman)"), "{text}");
    assert!(text.contains("predicted winner in measured top-2"), "{text}");
}

#[test]
fn validate_tuner_without_auto_fails_with_actionable_message() {
    let out = tamio()
        .args(["sweep", "--algorithm", "tam:2", "--validate-tuner"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--validate-tuner requires --algorithm auto"), "{err}");
}

#[test]
fn garbage_budget_reqs_fails_instead_of_substituting_the_default() {
    let out = tamio()
        .args(["table1", "--nodes", "2", "--ppn", "8", "--budget-reqs", "banana"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "a typo'd budget must not silently default");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--budget-reqs"), "error must name the flag: {err}");
    assert!(err.contains("banana"), "error must quote the bad value: {err}");
}

#[test]
fn garbage_list_entry_fails_instead_of_being_dropped() {
    let out = tamio()
        .args([
            "sweep", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--pl", "2,x",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "a typo'd list entry must not be dropped");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--pl"), "error must name the flag: {err}");
    assert!(err.contains("'x'"), "error must quote the bad entry: {err}");
}

#[test]
fn bad_tree_spec_fails_with_nonzero_exit() {
    let out = tamio()
        .args(["run", "--algorithm", "tree:rack=2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown tree level"));
}

#[test]
fn zero_count_tree_level_fails_with_actionable_message() {
    let out = tamio()
        .args(["run", "--algorithm", "tree:node=0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("zero aggregator count"), "{err}");
    assert!(err.contains("omit the level"), "{err}");
}

#[test]
fn duplicate_tree_level_fails_with_actionable_message() {
    let out = tamio()
        .args(["run", "--algorithm", "tree:socket=1,socket=2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("duplicate tree level 'socket'"), "{err}");
}

#[test]
fn unusable_plan_cache_path_fails_with_actionable_message() {
    // A path whose parent is a regular file can never become a directory.
    let dir = std::env::temp_dir().join("tamio_cli_plan_cache_bad");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let blocker = dir.join("occupied");
    std::fs::write(&blocker, b"file").unwrap();
    let bad = blocker.join("plans");
    let out = tamio()
        .args([
            "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
            "--plan-cache", bad.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("plan-cache"), "{err}");
    assert!(err.contains("occupied"), "error must name the path: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zero_plan_cache_size_fails_with_actionable_message() {
    let out = tamio()
        .args(["run", "--plan-cache-size", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("plan-cache-size must be at least 1"), "{err}");
}

#[test]
fn plan_cache_persists_across_invocations() {
    let dir = std::env::temp_dir().join("tamio_cli_plan_cache_dir");
    let _ = std::fs::remove_dir_all(&dir);
    let args = [
        "run", "--nodes", "2", "--ppn", "4", "--workload", "strided",
        "--algorithm", "tam:2", "--stripe_size", "4096", "--stripe_count", "4",
        "--verify",
    ];
    let run = |dir: &std::path::Path| {
        let out = tamio()
            .args(args)
            .args(["--plan-cache", dir.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let first = run(&dir);
    assert!(first.contains("plan-cache:"), "stats line missing:\n{first}");
    assert!(first.contains("1 stored"), "first run must persist:\n{first}");
    assert!(first.contains("verify[write]: 8/8 ranks OK"), "{first}");
    let second = run(&dir);
    assert!(second.contains("1 loaded"), "second run must load from disk:\n{second}");
    assert!(second.contains("verify[write]: 8/8 ranks OK"), "{second}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_direction_both_prints_write_and_read_panels() {
    // BTIO at tiny scale (P = 4 is square); the read panel only prints if
    // every bar's gathered bytes verified (experiments::ensure_verified).
    let out = tamio()
        .args([
            "sweep", "--nodes", "2", "--ppn", "2", "--workload", "btio",
            "--scale", "100000", "--stripe_size", "4096", "--stripe_count", "4",
            "--pl", "2,4", "--direction", "both",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("-- write panel --"), "missing write panel:\n{text}");
    assert!(text.contains("-- read panel --"), "missing read panel:\n{text}");
    assert!(text.contains("P_L=2") && text.contains("two-phase"), "{text}");
}

#[test]
fn config_file_applies_and_cli_overrides() {
    let dir = std::env::temp_dir().join("tamio_cli_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.toml");
    std::fs::write(
        &cfg,
        "nodes = 2\nppn = 4\nworkload = \"contig\"\n[net]\nalpha_inter = 5e-6\n",
    )
    .unwrap();
    let out = tamio()
        .args(["run", "--config", cfg.to_str().unwrap(), "--ppn", "8"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("2 nodes x 8 ppn"), "CLI override lost:\n{text}");
    assert!(text.contains("contig"));
}

#[test]
fn bad_flag_fails_with_nonzero_exit() {
    let out = tamio().args(["run", "--bogus-flag", "3"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown config key"));
}

#[test]
fn congest_reports_both_algorithms() {
    let out = tamio()
        .args(["congest", "--nodes", "2", "--ppn", "8", "--workload", "strided"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("two-phase"));
    assert!(text.contains("tam"));
}

#[test]
fn table1_prints_all_datasets() {
    let out = tamio()
        .args(["table1", "--nodes", "2", "--ppn", "8", "--budget-reqs", "20000"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for ds in ["e3sm-g", "e3sm-f", "s3d"] {
        assert!(text.contains(ds), "table1 missing {ds}");
    }
}
