//! Degraded-mode collectives: the acceptance pins for `--faults`.
//!
//! * Aggregator-dropout plan repair is **byte-verified**: for two-phase,
//!   TAM and a depth-2 tree, both directions, the degraded run produces
//!   bytes identical to the fault-free run.
//! * Fault schedules with `?` selectors are a pure function of
//!   `--fault-seed`: repeat runs are bit-identical.
//! * Transient OST faults are absorbed by bounded retry: the collective
//!   succeeds, reports its retries, and pays the backoff in `io_phase`.

use tamio::cluster::{RankPlacement, Topology};
use tamio::config::RunConfig;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read, run_collective_write, Algorithm, DirectionSpec, ExchangeArena,
};
use tamio::coordinator::merge::ReqBatch;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::plancache::{
    run_collective_read_degraded, run_collective_write_degraded,
};
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::tree::TreeSpec;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::faults::{self, FaultPlan};
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;
use tamio::workloads::WorkloadKind;

const FAULT_SEED: u64 = 42;

/// 2 nodes x 8 ranks over 2 sockets/node — deep enough for every
/// algorithm under test (two-phase depth 0, TAM depth 1, tree depth 2).
fn parts() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
    (
        Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block),
        NetParams::default(),
        CpuModel::default(),
        IoModel::default(),
        NativeEngine,
    )
}

fn ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
    (0..topo.nprocs())
        .map(|r| {
            let base = r as u64 * 200;
            let view = tamio::mpisim::FlatView::from_pairs(vec![(base, 120), (base + 150, 30)])
                .unwrap();
            (r, ReqBatch::new(view, deterministic_payload(21, r, 150)))
        })
        .collect()
}

fn extent(topo: &Topology) -> u64 {
    (topo.nprocs() as u64 - 1) * 200 + 180
}

/// Every algorithm with the dropout schedules its depth supports.
fn dropout_matrix() -> Vec<(Algorithm, Vec<&'static str>)> {
    vec![
        (Algorithm::TwoPhase, vec!["agg_drop=?"]),
        (
            Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
            vec!["agg_drop=?", "agg_drop=?@level:0"],
        ),
        (
            Algorithm::Tree(TreeSpec { per_socket: 2, per_node: 1, per_switch: 0 }),
            vec!["agg_drop=?", "agg_drop=?@level:0", "agg_drop=?@level:1"],
        ),
    ]
}

#[test]
fn aggregator_dropout_writes_bytes_identical_to_fault_free() {
    let (topo, net, cpu, io, eng) = parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let n = extent(&topo);
    for (algo, schedules) in dropout_matrix() {
        let mut baseline = LustreFile::new(LustreConfig::new(64, 4));
        run_collective_write(&ctx, algo, ranks(&topo), &mut baseline).unwrap();
        let want = baseline.read_at(0, n);
        for spec in schedules {
            let plan: FaultPlan = spec.parse().unwrap();
            let mut file = LustreFile::new(LustreConfig::new(64, 4));
            let mut arena = ExchangeArena::default();
            let outcome = run_collective_write_degraded(
                &ctx,
                algo,
                ranks(&topo),
                &mut file,
                &mut arena,
                None,
                &plan,
                FAULT_SEED,
            )
            .unwrap();
            assert_eq!(
                outcome.counters.repaired_plans,
                1,
                "{} + '{spec}' must report its repair",
                algo.name()
            );
            assert_eq!(
                file.read_at(0, n),
                want,
                "{} + '{spec}': degraded bytes differ from fault-free",
                algo.name()
            );
        }
    }
}

#[test]
fn aggregator_dropout_reads_bytes_identical_to_fault_free() {
    let (topo, net, cpu, io, eng) = parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    // One shared pre-populated file: agg_drop is a pure plan fault, so
    // the storage layer is untouched and both runs read the same image.
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.begin_round();
    for (r, batch) in ranks(&topo) {
        file.write_view(r, &batch.view, &batch.payload).unwrap();
    }
    let views: Vec<_> = ranks(&topo).into_iter().map(|(r, b)| (r, b.view)).collect();
    for (algo, schedules) in dropout_matrix() {
        let (want, _) = run_collective_read(&ctx, algo, views.clone(), &file).unwrap();
        for spec in schedules {
            let plan: FaultPlan = spec.parse().unwrap();
            let mut arena = ExchangeArena::default();
            let (got, outcome) = run_collective_read_degraded(
                &ctx,
                algo,
                views.clone(),
                &file,
                &mut arena,
                None,
                &plan,
                FAULT_SEED,
            )
            .unwrap();
            assert_eq!(outcome.counters.repaired_plans, 1);
            assert_eq!(
                got,
                want,
                "{} + '{spec}': degraded gathered bytes differ from fault-free",
                algo.name()
            );
        }
    }
}

#[test]
fn level_drops_reject_depths_the_plan_does_not_have() {
    let (topo, net, cpu, io, eng) = parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let plan: FaultPlan = "agg_drop=?@level:0".parse().unwrap();
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    let mut arena = ExchangeArena::default();
    let err = run_collective_write_degraded(
        &ctx,
        Algorithm::TwoPhase,
        ranks(&topo),
        &mut file,
        &mut arena,
        None,
        &plan,
        FAULT_SEED,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("level"),
        "depth-0 plans have no levels to drop from: {err}"
    );
}

#[test]
fn transient_faults_are_absorbed_and_backoff_is_charged_to_io_phase() {
    let (topo, net, cpu, io, eng) = parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let n = extent(&topo);
    let mut baseline = LustreFile::new(LustreConfig::new(64, 4));
    let base = run_collective_write(&ctx, Algorithm::TwoPhase, ranks(&topo), &mut baseline)
        .unwrap();
    assert_eq!(base.counters.retries, 0);
    assert_eq!(base.counters.backoff_units, 0);

    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.fail_ost_transient(1, 3).unwrap();
    let out = run_collective_write(&ctx, Algorithm::TwoPhase, ranks(&topo), &mut file).unwrap();
    // All three countdown ticks land on the first call site touching
    // OST 1, which retries until the OST heals.
    assert_eq!(out.counters.retries, 3, "three transient errors = three retries");
    assert_eq!(out.counters.backoff_units, faults::backoff_units(3));
    assert!(
        out.breakdown.io_phase
            >= base.breakdown.io_phase + faults::backoff_penalty(out.counters.backoff_units)
                - 1e-12,
        "backoff penalty must be folded into io_phase ({} vs {})",
        out.breakdown.io_phase,
        base.breakdown.io_phase
    );
    // The file still verifies byte-for-byte.
    assert_eq!(file.read_at(0, n), baseline.read_at(0, n));

    // Exhausting the retry budget turns the transient fault fatal.
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.fail_ost_transient(1, 1_000).unwrap();
    file.faults_mut().set_max_retries(2);
    let err = run_collective_write(&ctx, Algorithm::TwoPhase, ranks(&topo), &mut file)
        .unwrap_err();
    assert!(err.is_transient(), "exhaustion propagates the last transient error: {err}");
}

#[test]
fn fault_schedules_are_bit_identical_under_a_fixed_seed() {
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 8;
    cfg.sockets_per_node = 2;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 16, 4);
    cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
    cfg.direction = DirectionSpec::Write;
    cfg.verify = true;
    // OST 0 backs the file's first stripe, so the transient countdown is
    // guaranteed to fire; '?' in agg_drop exercises the seeded selector.
    cfg.faults =
        Some("ost_fail=0@transient:2,ost_slow=0.5x:0-1,agg_drop=?@level:0".parse().unwrap());
    cfg.fault_seed = FAULT_SEED;
    let run = |cfg: &RunConfig| {
        let mut out = tamio::experiments::run_once(cfg).unwrap();
        let (run, verify) = out.remove(0);
        assert!(verify.unwrap().passed(), "degraded run must verify");
        run
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.breakdown, b.breakdown, "repeat run must be bit-identical");
    assert_eq!(a.counters.retries, b.counters.retries);
    assert_eq!(a.counters.backoff_units, b.counters.backoff_units);
    assert_eq!(a.counters.repaired_plans, b.counters.repaired_plans);
    assert!(a.counters.retries > 0, "the transient clause must actually fire");
    assert_eq!(a.counters.repaired_plans, 1);
    // A different seed may resolve '?' elsewhere but still verifies.
    cfg.fault_seed = 7;
    let c = run(&cfg);
    assert_eq!(c.counters.repaired_plans, 1);
}
