//! Failure-injection tests: failed OSTs, protocol violations, degenerate
//! inputs — the pipeline must fail loudly and precisely, never corrupt.

use std::sync::atomic::{AtomicUsize, Ordering};

use tamio::cluster::{RankPlacement, Topology};
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{run_collective_read, run_collective_write, Algorithm};
use tamio::coordinator::merge::{sort_coalesce_pairs, ReqBatch};
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::tree::TreeSpec;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::error::Error;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::{FlatView, RankState};
use tamio::netmodel::NetParams;
use tamio::runtime::engine::{NativeEngine, SortEngine};

fn ctx_parts() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
    (
        Topology::new(2, 4),
        NetParams::default(),
        CpuModel::default(),
        IoModel::default(),
        NativeEngine,
    )
}

fn simple_ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
    (0..topo.nprocs())
        .map(|r| {
            let view = FlatView::from_pairs(vec![(r as u64 * 100, 100)]).unwrap();
            (r, ReqBatch::new(view, vec![r as u8; 100]))
        })
        .collect()
}

#[test]
fn failed_ost_surfaces_storage_error() {
    let (topo, net, cpu, io, eng) = ctx_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.fail_ost(2).unwrap();
    let err = run_collective_write(&ctx, Algorithm::TwoPhase, simple_ranks(&topo), &mut file)
        .unwrap_err();
    assert!(matches!(err, Error::StorageFailed { ost: 2, .. }), "got {err}");
}

#[test]
fn tam_with_failed_ost_also_fails_cleanly() {
    let (topo, net, cpu, io, eng) = ctx_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.fail_ost(0).unwrap();
    let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 2 });
    assert!(run_collective_write(&ctx, algo, simple_ranks(&topo), &mut file).is_err());
}

/// Engine that succeeds for the first `ok_calls` merges, then returns
/// `Err` — drives mid-round engine failures through the default
/// `merge_sorted` (concat + `merge_coalesce`) path.
struct FailingEngine {
    ok_calls: usize,
    calls: AtomicUsize,
}

impl FailingEngine {
    fn after(ok_calls: usize) -> Self {
        FailingEngine { ok_calls, calls: AtomicUsize::new(0) }
    }
}

impl SortEngine for FailingEngine {
    fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> tamio::Result<Vec<(u64, u64)>> {
        if self.calls.fetch_add(1, Ordering::SeqCst) >= self.ok_calls {
            return Err(Error::Runtime("injected engine failure".into()));
        }
        Ok(sort_coalesce_pairs(pairs))
    }

    fn name(&self) -> &'static str {
        "failing"
    }
}

/// Multi-round read pattern: every rank reads a contiguous block, so each
/// of several rounds performs at least one aggregator merge.
fn read_views(topo: &Topology) -> Vec<(usize, FlatView)> {
    (0..topo.nprocs())
        .map(|r| (r, FlatView::from_pairs(vec![(r as u64 * 256, 256)]).unwrap()))
        .collect()
}

#[test]
fn engine_error_mid_round_propagates_from_read() {
    let (topo, net, cpu, io, _) = ctx_parts();
    // 8 ranks × 256B over 4 aggregators at stripe 64 → 8 rounds; failing
    // after 4 successful merges puts the error in the middle of the round
    // loop, inside the parallel per-aggregator map.
    let eng = FailingEngine::after(4);
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let file = LustreFile::new(LustreConfig::new(64, 4));
    let err = run_collective_read(&ctx, Algorithm::TwoPhase, read_views(&topo), &file)
        .unwrap_err();
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
    assert!(eng.calls.load(Ordering::SeqCst) > 4, "failure must be mid-run");
}

#[test]
fn tam_read_engine_error_in_intra_merge_propagates() {
    let (topo, net, cpu, io, _) = ctx_parts();
    // Fail on the very first merge: the local-aggregator view merge.
    let eng = FailingEngine::after(0);
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let file = LustreFile::new(LustreConfig::new(64, 4));
    let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 2 });
    let err = run_collective_read(&ctx, algo, read_views(&topo), &file).unwrap_err();
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
}

#[test]
fn failed_ost_surfaces_storage_error_on_read() {
    let (topo, net, cpu, io, eng) = ctx_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    run_collective_write(&ctx, Algorithm::TwoPhase, simple_ranks(&topo), &mut file).unwrap();
    file.fail_ost(2).unwrap();
    for algo in [Algorithm::TwoPhase, Algorithm::Tam(TamConfig { total_local_aggregators: 2 })] {
        let err = run_collective_read(&ctx, algo, read_views(&topo), &file).unwrap_err();
        assert!(matches!(err, Error::StorageFailed { ost: 2, .. }), "{}: got {err}", algo.name());
    }
}

/// Depth-2 fixture: 2 nodes x 8 ranks over 2 sockets/node, aggregating
/// socket(2) -> node(1) -> 4 global aggregators.  Fragmented views keep
/// every stripe populated so any armed OST is hit promptly.
fn depth2_parts() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
    (
        Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block),
        NetParams::default(),
        CpuModel::default(),
        IoModel::default(),
        NativeEngine,
    )
}

fn depth2_spec() -> Algorithm {
    Algorithm::Tree(TreeSpec { per_socket: 2, per_node: 1, per_switch: 0 })
}

fn depth2_ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
    (0..topo.nprocs())
        .map(|r| {
            let base = r as u64 * 200;
            let view = FlatView::from_pairs(vec![(base, 120), (base + 150, 30)]).unwrap();
            (r, ReqBatch::new(view, deterministic_payload(21, r, 150)))
        })
        .collect()
}

/// Round index out of a `... exchange round <r>, aggregator <a> ...` task
/// label (the worker pool stamps every storage error with its task
/// identity).
fn exchange_round_of(msg: &str) -> u64 {
    let tail = &msg[msg.find("exchange round ").expect("task label") + "exchange round ".len()..];
    tail.chars()
        .take_while(char::is_ascii_digit)
        .collect::<String>()
        .parse()
        .expect("round index in task label")
}

#[test]
fn depth2_mid_round_write_failure_names_its_task() {
    let (topo, net, cpu, io, eng) = depth2_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    // Learn the fault-free round structure first, so "mid-round" is a
    // checked property of the fixture rather than an assumption.
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    let rounds = run_collective_write(&ctx, depth2_spec(), depth2_ranks(&topo), &mut file)
        .unwrap()
        .counters
        .rounds;
    assert!(rounds >= 4, "fixture must be multi-round, got {rounds}");
    // Re-run with OST 1 armed to fail persistently at round 2.
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.arm_ost_fault(2, 1, None).unwrap();
    let err = run_collective_write(&ctx, depth2_spec(), depth2_ranks(&topo), &mut file)
        .unwrap_err();
    assert!(matches!(err, Error::StorageFailed { ost: 1, .. }), "got {err}");
    let msg = err.to_string();
    assert!(msg.contains("write exchange round "), "no task identity in: {msg}");
    assert!(msg.contains(", aggregator "), "no aggregator identity in: {msg}");
    let round = exchange_round_of(&msg);
    assert!(
        (2..rounds).contains(&round),
        "armed at round 2 but failed at round {round} of {rounds}: {msg}"
    );
}

#[test]
fn depth2_mid_round_read_failure_names_its_task() {
    let (topo, net, cpu, io, eng) = depth2_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    // Pre-populate with plain per-rank writes (the operation under test
    // is the collective read), then learn the round structure fault-free.
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    file.begin_round();
    for (r, batch) in depth2_ranks(&topo) {
        file.write_view(r, &batch.view, &batch.payload).unwrap();
    }
    let views: Vec<_> =
        depth2_ranks(&topo).into_iter().map(|(r, b)| (r, b.view)).collect();
    let (_, outcome) = run_collective_read(&ctx, depth2_spec(), views.clone(), &file).unwrap();
    let rounds = outcome.counters.rounds;
    assert!(rounds >= 4, "fixture must be multi-round, got {rounds}");
    // Arm OST 1 at round 2 and restart the round clock — the setup above
    // must not have consumed the schedule.
    file.arm_ost_fault(2, 1, None).unwrap();
    file.reset_fault_rounds();
    let err = run_collective_read(&ctx, depth2_spec(), views, &file).unwrap_err();
    assert!(matches!(err, Error::StorageFailed { ost: 1, .. }), "got {err}");
    let msg = err.to_string();
    assert!(msg.contains("read exchange round "), "no task identity in: {msg}");
    assert!(msg.contains(", aggregator "), "no aggregator identity in: {msg}");
    let round = exchange_round_of(&msg);
    assert!(
        (2..rounds).contains(&round),
        "armed at round 2 but failed at round {round} of {rounds}: {msg}"
    );
}

#[test]
fn fail_ost_rejects_out_of_range_indices() {
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    let err = file.fail_ost(4).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "got {err}");
    assert!(err.to_string().contains("0..4"), "{err}");
    assert!(file.fail_ost_transient(7, 2).is_err());
    assert!(file.arm_ost_fault(1, 9, None).is_err());
    assert!(file.set_ost_rate(5, 0.5).is_err());
    // In-range installs still work after the rejections.
    file.fail_ost(3).unwrap();
}

#[test]
fn unsorted_view_rejected_at_construction() {
    let err = FlatView::from_pairs(vec![(100, 4), (0, 4)]).unwrap_err();
    assert!(matches!(err, Error::Protocol(_)));
}

#[test]
fn payload_size_mismatch_rejected() {
    let view = FlatView::from_pairs(vec![(0, 10)]).unwrap();
    assert!(RankState::with_payload(0, view, vec![1, 2, 3]).is_err());
}

#[test]
fn empty_and_zero_length_ranks_are_fine() {
    let (topo, net, cpu, io, eng) = ctx_parts();
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    // Rank 0 writes, everyone else posts empty views or zero-length reqs.
    let mut ranks = vec![(
        0usize,
        ReqBatch::new(FlatView::from_pairs(vec![(0, 64)]).unwrap(), vec![9u8; 64]),
    )];
    for r in 1..topo.nprocs() {
        let view = if r % 2 == 0 {
            FlatView::empty()
        } else {
            FlatView::from_pairs(vec![(128, 0)]).unwrap()
        };
        ranks.push((r, ReqBatch::new(view, vec![])));
    }
    let mut file = LustreFile::new(LustreConfig::new(64, 4));
    let out = run_collective_write(
        &ctx,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        ranks,
        &mut file,
    )
    .unwrap();
    assert_eq!(file.read_at(0, 64), vec![9u8; 64]);
    assert_eq!(out.counters.bytes, 64);
}

#[test]
fn oversized_offsets_rejected_by_validate() {
    let v = FlatView::from_pairs_unchecked(vec![u64::MAX - 2], vec![100]);
    assert!(v.validate().is_err());
}

#[test]
fn config_rejects_unknown_and_malformed_keys() {
    use tamio::config::{KvMap, RunConfig};
    let mut cfg = RunConfig::default();
    assert!(cfg
        .apply(&KvMap::from_pairs(vec![("nodes".into(), "NaN".into())]))
        .is_err());
    assert!(cfg
        .apply(&KvMap::from_pairs(vec![("placement".into(), "diagonal".into())]))
        .is_err());
    assert!(cfg
        .apply(&KvMap::from_pairs(vec![("workload".into(), "hpl".into())]))
        .is_err());
}

#[test]
fn btio_non_square_process_count_is_a_workload_error() {
    use tamio::workloads::{Workload, WorkloadKind};
    let topo = Topology::new(2, 4);
    let w = WorkloadKind::Btio.build(4096);
    let err = w.view(&topo, 0).unwrap_err();
    assert!(matches!(err, Error::Workload(_)));
}
