//! Equivalence tests for the streaming aggregator hot path: the
//! `merge_sorted` engine entry point must be bit-identical to
//! sort+coalesce of the concatenation for every engine, the two-pointer
//! payload scatter must match the binary-search reference (including
//! overlapping and zero-length segments), and the dense-rank phase cost
//! accounting must match a hash-map reference.

use std::collections::HashMap;

use tamio::cluster::Topology;
use tamio::coordinator::merge::{
    scatter_into, scatter_into_binary_search, scatter_into_buf, sort_coalesce_pairs, ReqBatch,
};
use tamio::mpisim::FlatView;
use tamio::netmodel::phase::{cost_phase, cost_phase_with_pending, Message, PendingQueue};
use tamio::netmodel::{NetParams, SendMode};
use tamio::runtime::engine::{NativeEngine, SortEngine, XlaEngine};
use tamio::util::SplitMix64;

/// `k` sorted streams built from one global request sequence dealt out in
/// runs, with zero-length requests mixed in; disjoint in file space.
fn random_streams(rng: &mut SplitMix64, k: usize, total: usize) -> Vec<FlatView> {
    let run = 1 + rng.gen_range(6) as usize;
    let mut streams: Vec<Vec<(u64, u64)>> = vec![Vec::new(); k];
    let mut cursor = rng.gen_range(128);
    for i in 0..total {
        let s = (i / run) % k;
        let len = rng.gen_range(48); // includes zero-length requests
        if rng.gen_bool(0.5) {
            cursor += rng.gen_range(256);
        }
        streams[s].push((cursor, len));
        cursor += len;
    }
    streams
        .into_iter()
        .map(|pairs| FlatView::from_pairs(pairs).unwrap())
        .collect()
}

/// Deterministic payload for a view (distinct per stream index).
fn payload_for(view: &FlatView, tag: u8) -> Vec<u8> {
    (0..view.total_bytes()).map(|i| (i as u8).wrapping_mul(31) ^ tag).collect()
}

fn assert_merge_sorted_matches_reference(engine: &dyn SortEngine, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    for case in 0..60 {
        let k = 1 + rng.gen_range(12) as usize;
        let total = rng.gen_range(400) as usize;
        let streams = random_streams(&mut rng, k, total);
        let refs: Vec<&FlatView> = streams.iter().collect();
        let merged = engine.merge_sorted(&refs).unwrap();
        let concat: Vec<(u64, u64)> = streams.iter().flat_map(|v| v.iter()).collect();
        let want = sort_coalesce_pairs(concat);
        assert_eq!(
            merged.iter().collect::<Vec<_>>(),
            want,
            "engine '{}' diverged from sort+coalesce (case {case}, k={k}, n={total})",
            engine.name()
        );
        merged.validate().unwrap();
    }
}

#[test]
fn native_merge_sorted_matches_sort_coalesce_of_concat() {
    assert_merge_sorted_matches_reference(&NativeEngine, 0xAB5E);
}

/// The default-trait fallback path (what the XLA engine inherits):
/// concatenate, then `merge_coalesce`.
struct ConcatFallback;

impl SortEngine for ConcatFallback {
    fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> tamio::Result<Vec<(u64, u64)>> {
        Ok(sort_coalesce_pairs(pairs))
    }

    fn name(&self) -> &'static str {
        "concat-fallback"
    }
}

#[test]
fn fallback_merge_sorted_matches_sort_coalesce_of_concat() {
    assert_merge_sorted_matches_reference(&ConcatFallback, 0xAB5E);
}

#[test]
fn xla_merge_sorted_matches_native() {
    let xla = match XlaEngine::load_default() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[skip] xla engine unavailable: {e}");
            return;
        }
    };
    let mut rng = SplitMix64::new(0x71A);
    for _ in 0..10 {
        let k = 1 + rng.gen_range(10) as usize;
        let total = rng.gen_range(2000) as usize;
        let streams = random_streams(&mut rng, k, total);
        let refs: Vec<&FlatView> = streams.iter().collect();
        let native = NativeEngine.merge_sorted(&refs).unwrap();
        let got = xla.merge_sorted(&refs).unwrap();
        assert_eq!(got, native, "xla merge_sorted != native (k={k}, n={total})");
    }
}

#[test]
fn scatter_two_pointer_matches_binary_search_randomized() {
    let mut rng = SplitMix64::new(0x5CA7);
    for case in 0..80 {
        let k = 1 + rng.gen_range(8) as usize;
        let total = rng.gen_range(300) as usize;
        let streams = random_streams(&mut rng, k, total);
        let batches: Vec<ReqBatch> = streams
            .into_iter()
            .enumerate()
            .map(|(i, v)| {
                let p = payload_for(&v, i as u8);
                ReqBatch::new(v, p)
            })
            .collect();
        let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
        let merged = NativeEngine.merge_sorted(&views).unwrap();

        let (p_two, m_two) = scatter_into(&merged, &batches);
        let (p_bin, m_bin) = scatter_into_binary_search(&merged, &batches);
        assert_eq!(p_two, p_bin, "payload mismatch (case {case})");
        assert_eq!(m_two, m_bin, "moved-bytes mismatch (case {case})");
    }
}

#[test]
fn scatter_handles_overlapping_and_zero_length_segments() {
    // Overlapping writers (later batch wins, distinct offsets) plus
    // zero-length requests both inside and between merged segments: the
    // merged view is deliberately *not* fully coalesced across overlaps.
    let a = ReqBatch::new(
        FlatView::from_pairs(vec![(0, 8), (8, 0), (20, 4)]).unwrap(),
        vec![1u8; 12],
    );
    let b = ReqBatch::new(
        FlatView::from_pairs(vec![(2, 4), (21, 2), (30, 0)]).unwrap(),
        vec![2u8; 6],
    );
    let views: Vec<&FlatView> = vec![&a.view, &b.view];
    let merged = NativeEngine.merge_sorted(&views).unwrap();
    let batches = [a, b];
    let (p_two, m_two) = scatter_into(&merged, &batches);
    let (p_bin, m_bin) = scatter_into_binary_search(&merged, &batches);
    assert_eq!(p_two, p_bin);
    assert_eq!(m_two, m_bin);
    assert_eq!(m_two, 18);
}

#[test]
fn scatter_into_buf_steady_state_reuses_capacity() {
    let mut rng = SplitMix64::new(0xBEEF);
    let mut buf = Vec::new();
    for round in 0..10 {
        let streams = random_streams(&mut rng, 4, 100);
        let batches: Vec<ReqBatch> = streams
            .into_iter()
            .enumerate()
            .map(|(i, v)| {
                let p = payload_for(&v, i as u8 ^ round);
                ReqBatch::new(v, p)
            })
            .collect();
        let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
        let merged = NativeEngine.merge_sorted(&views).unwrap();
        let moved = scatter_into_buf(&merged, &batches, &mut buf);
        let (want, want_moved) = scatter_into_binary_search(&merged, &batches);
        assert_eq!(buf, want, "round {round}");
        assert_eq!(moved, want_moved);
    }
}

// ---- dense-rank phase accounting vs a hash-map reference ----

/// The pre-tentpole hash-map implementation, kept verbatim as the oracle.
fn cost_phase_hashmap_reference(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &HashMap<usize, u64>,
) -> (f64, f64, f64, f64, usize, u64) {
    let mut recv_time: HashMap<usize, f64> = HashMap::new();
    let mut send_time: HashMap<usize, f64> = HashMap::new();
    let mut nic_time: HashMap<usize, f64> = HashMap::new();
    let mut in_degree: HashMap<usize, usize> = HashMap::new();
    let mut total_bytes = 0u64;
    for m in msgs {
        let intra = topo.same_node(m.src, m.dst);
        let wire = params.msg_cost(intra, m.bytes);
        let pending = *pending_per_receiver.get(&m.dst).unwrap_or(&0) as f64;
        *recv_time.entry(m.dst).or_default() +=
            params.recv_overhead + wire + pending * params.pending_penalty;
        *send_time.entry(m.src).or_default() +=
            params.send_overhead + if intra { 0.0 } else { m.bytes as f64 * params.beta_inter };
        if !intra {
            *nic_time.entry(topo.node_of(m.dst)).or_default() +=
                m.bytes as f64 * params.nic_ingest;
        }
        *in_degree.entry(m.dst).or_default() += 1;
        total_bytes += m.bytes;
    }
    let recv = recv_time.values().cloned().fold(0.0, f64::max);
    let send = send_time.values().cloned().fold(0.0, f64::max);
    let nic = nic_time.values().cloned().fold(0.0, f64::max);
    (
        recv.max(send).max(nic),
        recv,
        send,
        nic,
        in_degree.values().cloned().max().unwrap_or(0),
        total_bytes,
    )
}

fn random_msgs(rng: &mut SplitMix64, topo: &Topology, n: usize) -> Vec<Message> {
    let p = topo.nprocs() as u64;
    (0..n)
        .map(|_| {
            Message::new(
                rng.gen_range(p) as usize,
                rng.gen_range(p) as usize,
                rng.gen_range(1 << 16),
            )
        })
        .collect()
}

#[test]
fn dense_cost_phase_matches_hashmap_reference() {
    let mut rng = SplitMix64::new(0xDE45E);
    let params = NetParams::default();
    for _ in 0..50 {
        let topo = Topology::new(1 + rng.gen_range(8) as usize, 1 + rng.gen_range(16) as usize);
        let msgs = random_msgs(&mut rng, &topo, rng.gen_range(200) as usize);
        // Random pending counts on a subset of receivers.
        let mut pending_dense = vec![0u64; topo.nprocs()];
        let mut pending_map = HashMap::new();
        for _ in 0..rng.gen_range(10) {
            let r = rng.gen_range(topo.nprocs() as u64) as usize;
            let c = rng.gen_range(50);
            pending_dense[r] = c;
            pending_map.insert(r, c);
        }
        let got = cost_phase_with_pending(&params, &topo, &msgs, &pending_dense);
        let (time, recv, send, nic, max_in, bytes) =
            cost_phase_hashmap_reference(&params, &topo, &msgs, &pending_map);
        assert_eq!(got.time, time);
        assert_eq!(got.recv_bound, recv);
        assert_eq!(got.send_bound, send);
        assert_eq!(got.nic_bound, nic);
        assert_eq!(got.max_in_degree, max_in);
        assert_eq!(got.total_bytes, bytes);
        assert_eq!(got.n_messages, msgs.len());
    }
}

#[test]
fn dense_pending_queue_matches_reference_across_rounds() {
    let mut params = NetParams::default();
    params.send_mode = SendMode::Isend;
    let topo = Topology::new(4, 8);
    let mut rng = SplitMix64::new(0x9E0);
    let mut q = PendingQueue::new();
    let mut pending_ref: HashMap<usize, u64> = HashMap::new();
    for _ in 0..20 {
        let msgs = random_msgs(&mut rng, &topo, 64);
        let got = q.cost_round(&params, &topo, &msgs);
        let (time, ..) = cost_phase_hashmap_reference(&params, &topo, &msgs, &pending_ref);
        assert_eq!(got.time, time);
        for m in &msgs {
            *pending_ref.entry(m.dst).or_default() += 1;
        }
    }
    for r in 0..topo.nprocs() {
        assert_eq!(q.pending_for(r), *pending_ref.get(&r).unwrap_or(&0), "rank {r}");
    }
    // cost_phase (no pending) equals a round under Issend semantics.
    params.send_mode = SendMode::Issend;
    let msgs = random_msgs(&mut rng, &topo, 64);
    let mut q2 = PendingQueue::new();
    let a = q2.cost_round(&params, &topo, &msgs);
    let b = cost_phase(&params, &topo, &msgs);
    assert_eq!(a.time, b.time);
    assert_eq!(q2.pending_for(0), 0);
}
