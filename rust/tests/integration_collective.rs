//! Integration tests: full collective write/read across workloads,
//! algorithms and topologies, verified against a reference file image.

use tamio::cluster::Topology;
use tamio::config::RunConfig;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{run_collective_read, run_collective_write, Algorithm};
use tamio::coordinator::merge::ReqBatch;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::experiments::run_once;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;
use tamio::workloads::WorkloadKind;

struct Fx {
    topo: Topology,
    net: NetParams,
    cpu: CpuModel,
    io: IoModel,
    eng: NativeEngine,
}

impl Fx {
    fn new(nodes: usize, ppn: usize) -> Self {
        Fx {
            topo: Topology::new(nodes, ppn),
            net: NetParams::default(),
            cpu: CpuModel::default(),
            io: IoModel::default(),
            eng: NativeEngine,
        }
    }

    fn ctx(&self, n_agg: usize) -> CollectiveCtx<'_> {
        CollectiveCtx {
            topo: &self.topo,
            net: &self.net,
            cpu: &self.cpu,
            io: &self.io,
            engine: &self.eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: n_agg,
        }
    }
}

/// Reference image: apply every rank's writes in rank order to a flat
/// buffer (the MPI result for non-overlapping collective writes).
fn reference_image(ranks: &[(usize, ReqBatch)]) -> (u64, Vec<u8>) {
    let hi = ranks
        .iter()
        .filter_map(|(_, b)| b.view.max_end())
        .max()
        .unwrap_or(0);
    let mut img = vec![0u8; hi as usize];
    for (_, b) in ranks {
        let mut cursor = 0usize;
        for (off, len) in b.view.iter() {
            img[off as usize..(off + len) as usize]
                .copy_from_slice(&b.payload[cursor..cursor + len as usize]);
            cursor += len as usize;
        }
    }
    (hi, img)
}

fn check_workload(kind: WorkloadKind, algo: Algorithm, nodes: usize, ppn: usize, scale: u64) {
    let fx = Fx::new(nodes, ppn);
    let ctx = fx.ctx(8);
    let w = kind.build(scale);
    let ranks = w.generate(&fx.topo, 99).unwrap();
    let (hi, img) = reference_image(&ranks);
    let mut file = LustreFile::new(LustreConfig::new(1 << 14, 8));
    let out = run_collective_write(&ctx, algo, ranks, &mut file).unwrap();
    assert_eq!(
        file.read_at(0, hi),
        img,
        "{kind} {} file image mismatch",
        algo.name()
    );
    assert_eq!(out.counters.lock_conflicts, 0, "{kind}: stripe-aligned domains must not conflict");
}

#[test]
fn e3sm_g_two_phase_and_tam_match_reference() {
    check_workload(WorkloadKind::E3smG, Algorithm::TwoPhase, 2, 8, 50_000);
    check_workload(
        WorkloadKind::E3smG,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        2,
        8,
        50_000,
    );
}

#[test]
fn e3sm_f_tam_matches_reference() {
    check_workload(
        WorkloadKind::E3smF,
        Algorithm::Tam(TamConfig { total_local_aggregators: 8 }),
        2,
        8,
        200_000,
    );
}

#[test]
fn btio_both_algorithms_match_reference() {
    // P = 16 (square) — BTIO requirement.
    check_workload(WorkloadKind::Btio, Algorithm::TwoPhase, 2, 8, 100_000);
    check_workload(
        WorkloadKind::Btio,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        2,
        8,
        100_000,
    );
}

#[test]
fn s3d_both_algorithms_match_reference() {
    check_workload(WorkloadKind::S3d, Algorithm::TwoPhase, 2, 8, 50_000);
    check_workload(
        WorkloadKind::S3d,
        Algorithm::Tam(TamConfig { total_local_aggregators: 2 }),
        2,
        8,
        50_000,
    );
}

#[test]
fn tam_and_two_phase_produce_identical_files() {
    for kind in [WorkloadKind::Strided, WorkloadKind::Contig, WorkloadKind::S3d] {
        let fx = Fx::new(2, 8);
        let ctx = fx.ctx(4);
        // Scale divisor shrinks the paper-size datasets (S3D at scale 1
        // is 61 GiB); synthetic workloads ignore it.
        let w = kind.build(100_000);
        let ranks = w.generate(&fx.topo, 5).unwrap();
        let hi = ranks.iter().filter_map(|(_, b)| b.view.max_end()).max().unwrap();
        let mut f1 = LustreFile::new(LustreConfig::new(1 << 12, 4));
        let mut f2 = LustreFile::new(LustreConfig::new(1 << 12, 4));
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut f1).unwrap();
        run_collective_write(
            &ctx,
            Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
            ranks,
            &mut f2,
        )
        .unwrap();
        assert_eq!(f1.read_at(0, hi), f2.read_at(0, hi), "{kind}");
    }
}

#[test]
fn read_inverts_write_for_all_workloads() {
    for kind in [WorkloadKind::Strided, WorkloadKind::Btio, WorkloadKind::S3d] {
        let fx = Fx::new(2, 8);
        let ctx = fx.ctx(4);
        let w = kind.build(100_000);
        let ranks = w.generate(&fx.topo, 21).unwrap();
        let mut file = LustreFile::new(LustreConfig::new(1 << 13, 4));
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        for algo in [
            Algorithm::TwoPhase,
            Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        ] {
            let views: Vec<_> = ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
            let (got, _) = run_collective_read(&ctx, algo, views, &file).unwrap();
            for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
                assert_eq!(payload, &want.payload, "{kind} {} rank {r}", algo.name());
            }
        }
    }
}

#[test]
fn multi_round_boundary_exact_stripe_multiples() {
    // Aggregate region exactly n_agg stripes -> 1 round; +1 byte -> 2.
    let fx = Fx::new(1, 4);
    let ctx = fx.ctx(4);
    let stripe = 1024u64;
    for extra in [0u64, 1] {
        let total = 4 * stripe + extra;
        let view = tamio::mpisim::FlatView::from_pairs(vec![(0, total)]).unwrap();
        let payload = vec![7u8; total as usize];
        let ranks = vec![(0usize, ReqBatch::new(view, payload))];
        let mut file = LustreFile::new(LustreConfig::new(stripe, 4));
        let out = run_collective_write(&ctx, Algorithm::TwoPhase, ranks, &mut file).unwrap();
        assert_eq!(out.counters.rounds, 1 + u64::from(extra > 0));
        assert_eq!(file.read_at(0, total), vec![7u8; total as usize]);
    }
}

#[test]
fn non_divisible_process_counts_work() {
    // 3 nodes x 5 ppn, P_L=7: uneven everywhere.
    let fx = Fx::new(3, 5);
    let ctx = fx.ctx(3);
    let w = WorkloadKind::Strided.build(100_000);
    let ranks = w.generate(&fx.topo, 1).unwrap();
    let (hi, img) = reference_image(&ranks);
    let mut file = LustreFile::new(LustreConfig::new(1 << 12, 3));
    run_collective_write(
        &ctx,
        Algorithm::Tam(TamConfig { total_local_aggregators: 7 }),
        ranks,
        &mut file,
    )
    .unwrap();
    assert_eq!(file.read_at(0, hi), img);
}

#[test]
fn pl_sweep_intra_monotone_inter_growing() {
    // §IV-D: f(P_L) decreasing, g(P_L) increasing (communication part).
    let mut cfg = RunConfig::default();
    cfg.nodes = 4;
    cfg.ppn = 16;
    cfg.workload = WorkloadKind::E3smG;
    cfg.scale = 2048;
    let runs = tamio::experiments::breakdown_sweep(&cfg, &[4, 16, 64]).unwrap();
    assert!(runs[0].breakdown.intra_total() > runs[2].breakdown.intra_total());
    assert!(runs[0].counters.msgs_inter <= runs[2].counters.msgs_inter);
}

#[test]
fn two_phase_equivalent_to_tam_with_pl_eq_p() {
    // §IV-D: P_L == P makes TAM's exchange structurally identical.
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 8;
    cfg.workload = WorkloadKind::Strided;
    cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 16 });
    let (tam_run, _) = run_once(&cfg).unwrap().remove(0);
    cfg.algorithm = Algorithm::TwoPhase;
    let (two_run, _) = run_once(&cfg).unwrap().remove(0);
    assert_eq!(tam_run.counters.msgs_intra, 0);
    assert_eq!(tam_run.counters.msgs_inter, two_run.counters.msgs_inter);
    assert_eq!(tam_run.counters.max_in_degree, two_run.counters.max_in_degree);
    assert!((tam_run.breakdown.inter_comm - two_run.breakdown.inter_comm).abs() < 1e-12);
}

#[test]
fn congestion_shrinks_with_tam_at_scale() {
    // P = 1024 > P_L = 256 so TAM's aggregation layer is active.
    let mut cfg = RunConfig::default();
    cfg.nodes = 16;
    cfg.ppn = 64;
    cfg.workload = WorkloadKind::E3smG;
    cfg.scale = 8192;
    let rows = tamio::experiments::fig2_congestion(&cfg).unwrap();
    let (two, tam) = (&rows[0], &rows[1]);
    assert!(tam.1 < two.1, "TAM in-degree {} !< two-phase {}", tam.1, two.1);
}
