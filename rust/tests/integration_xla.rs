//! XLA-engine integration: the AOT-compiled JAX/Pallas aggregation
//! pipeline must agree bit-for-bit with the native engine, standalone and
//! inside full collectives.  Tests skip (with a notice) when artifacts
//! have not been built (`make artifacts`).

use tamio::config::RunConfig;
use tamio::coordinator::collective::Algorithm;
use tamio::coordinator::merge::sort_coalesce_pairs;
use tamio::coordinator::tam::TamConfig;
use tamio::experiments::{run_once, run_once_with_engine};
use tamio::lustre::LustreConfig;
use tamio::runtime::engine::{EngineKind, SortEngine, XlaEngine};
use tamio::util::SplitMix64;
use tamio::workloads::WorkloadKind;

fn xla_or_skip() -> Option<XlaEngine> {
    match XlaEngine::load_default() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("[skip] xla engine unavailable: {e}");
            None
        }
    }
}

fn random_pairs(n: usize, seed: u64) -> Vec<(u64, u64)> {
    let mut rng = SplitMix64::new(seed);
    let mut cursor = 0u64;
    let mut pairs = Vec::with_capacity(n);
    for _ in 0..n {
        let len = rng.gen_range(64); // includes zero-length requests
        cursor += if rng.gen_bool(0.4) { 0 } else { rng.gen_range(128) };
        pairs.push((cursor, len));
        cursor += len;
    }
    rng.shuffle(&mut pairs);
    pairs
}

#[test]
fn xla_matches_native_on_random_batches() {
    let Some(xla) = xla_or_skip() else { return };
    for n in [0usize, 1, 2, 100, 255, 256, 257, 1024, 5000, 20_000] {
        let pairs = random_pairs(n, n as u64 + 1);
        let native = sort_coalesce_pairs(pairs.clone());
        let got = xla.merge_coalesce(pairs).unwrap();
        assert_eq!(got, native, "n={n}");
    }
}

#[test]
fn xla_handles_extreme_offsets() {
    let Some(xla) = xla_or_skip() else { return };
    // Offsets near 2^62 (file offsets are < 2^63 by MPI convention).
    let big = 1u64 << 62;
    let pairs = vec![(big, 10), (big + 10, 5), (0, 3), (big + 100, 1)];
    let got = xla.merge_coalesce(pairs.clone()).unwrap();
    assert_eq!(got, sort_coalesce_pairs(pairs));
}

#[test]
fn full_collective_identical_under_both_engines() {
    let Some(xla) = xla_or_skip() else { return };
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 8;
    cfg.workload = WorkloadKind::Btio;
    cfg.scale = 100_000;
    cfg.lustre = LustreConfig::new(1 << 14, 8);
    cfg.verify = true;
    cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });

    let (xla_run, xla_verify) = run_once_with_engine(&cfg, &xla).unwrap().remove(0);
    assert!(xla_verify.unwrap().passed(), "xla engine verification");

    cfg.engine = EngineKind::Native;
    let (native_run, native_verify) = run_once(&cfg).unwrap().remove(0);
    assert!(native_verify.unwrap().passed());

    // Identical aggregation results -> identical counters and times.
    assert_eq!(xla_run.counters.reqs_after_intra, native_run.counters.reqs_after_intra);
    assert_eq!(xla_run.counters.reqs_at_io, native_run.counters.reqs_at_io);
    assert_eq!(xla_run.counters.msgs_inter, native_run.counters.msgs_inter);
    assert!((xla_run.breakdown.total() - native_run.breakdown.total()).abs() < 1e-12);
}
