//! Plan-oracle integration tests (§Plan cache tentpole): a warm cache
//! hit must execute bit-identically to a cold build (file bytes,
//! simulated breakdown, and counters), plans must round-trip through the
//! versioned on-disk format, and corrupt or stale files must be rejected
//! gracefully (rebuild, never crash).

use tamio::cluster::Topology;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read_with, run_collective_write_with, Algorithm, Direction, ExchangeArena,
};
use tamio::coordinator::merge::ReqBatch;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::plancache::{
    run_collective_read_cached, run_collective_write_cached, PlanCache, PLAN_FORMAT_VERSION,
};
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;

const STRIPE: u64 = 256;
const N_OST: usize = 4;

/// A fresh scratch directory under the system temp dir (unique per
/// test so parallel test binaries don't collide).
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tamio_plan_cache_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

struct Fixture {
    topo: Topology,
    net: NetParams,
    cpu: CpuModel,
    io: IoModel,
    eng: NativeEngine,
}

impl Fixture {
    fn new() -> Self {
        Fixture {
            topo: Topology::new(2, 8),
            net: NetParams::default(),
            cpu: CpuModel::default(),
            io: IoModel::default(),
            eng: NativeEngine,
        }
    }

    fn ctx(&self) -> CollectiveCtx<'_> {
        CollectiveCtx {
            topo: &self.topo,
            net: &self.net,
            cpu: &self.cpu,
            io: &self.io,
            engine: &self.eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: N_OST,
        }
    }

    /// Per-rank batches: 8 strided pieces per rank, deterministic bytes.
    fn ranks(&self) -> Vec<(usize, ReqBatch)> {
        (0..self.topo.nprocs())
            .map(|r| {
                let base = r as u64 * 2048;
                let view = FlatView::from_pairs(
                    (0..8).map(|i| (base + i * 256, 200)).collect(),
                )
                .unwrap();
                (r, ReqBatch::new(view, deterministic_payload(31, r, 1600)))
            })
            .collect()
    }
}

/// Read every rank's view back out of the file image.
fn image_of(file: &LustreFile, ranks: &[(usize, ReqBatch)]) -> Vec<Vec<u8>> {
    ranks
        .iter()
        .map(|(_, b)| {
            let mut got = Vec::new();
            for (off, len) in b.view.iter() {
                got.extend_from_slice(&file.read_at(off, len));
            }
            got
        })
        .collect()
}

/// A warm cache hit must be observably identical to the cold build: the
/// same file bytes, the same simulated [`Breakdown`] (including the
/// `plan` component — plan *time* is simulated at execute time, so a hit
/// only removes wall-clock work), and the same counters — and identical
/// to the uncached ad-hoc path too.  All three algorithm families.
#[test]
fn warm_hit_is_bit_identical_to_cold_build() {
    let fx = Fixture::new();
    let ctx = fx.ctx();
    let ranks = fx.ranks();
    for (label, algo) in [
        ("two-phase", Algorithm::TwoPhase),
        (
            "tam",
            Algorithm::Tam(tamio::coordinator::tam::TamConfig { total_local_aggregators: 4 }),
        ),
        ("tree", Algorithm::Tree("socket=2,node=1".parse().unwrap())),
    ] {
        let mut cache = PlanCache::in_memory(4);
        let mut arena = ExchangeArena::default();

        // Uncached reference.
        let mut file_ref = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
        let out_ref =
            run_collective_write_with(&ctx, algo, ranks.clone(), &mut file_ref, &mut arena)
                .unwrap();

        // Cold build through the cache (miss), then warm repeat (hit).
        let mut file_cold = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
        let out_cold = run_collective_write_cached(
            &ctx,
            algo,
            ranks.clone(),
            &mut file_cold,
            &mut arena,
            &mut cache,
        )
        .unwrap();
        let mut file_warm = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
        let out_warm = run_collective_write_cached(
            &ctx,
            algo,
            ranks.clone(),
            &mut file_warm,
            &mut arena,
            &mut cache,
        )
        .unwrap();
        assert_eq!(cache.stats.builds, 1, "{label}: first cached run must build");
        assert_eq!(cache.stats.hits, 1, "{label}: second cached run must hit");

        assert_eq!(
            image_of(&file_cold, &ranks),
            image_of(&file_warm, &ranks),
            "{label}: warm-hit file bytes differ from cold-build"
        );
        assert_eq!(
            image_of(&file_ref, &ranks),
            image_of(&file_cold, &ranks),
            "{label}: cached file bytes differ from uncached"
        );
        assert_eq!(
            out_cold.breakdown, out_warm.breakdown,
            "{label}: warm-hit breakdown differs from cold-build"
        );
        assert_eq!(
            out_ref.breakdown, out_cold.breakdown,
            "{label}: cached breakdown differs from uncached"
        );
        assert!(out_cold.breakdown.plan > 0.0, "{label}: plan time must be simulated");
        assert_eq!(
            format!("{:?}", out_cold.counters),
            format!("{:?}", out_warm.counters),
            "{label}: warm-hit counters differ from cold-build"
        );

        // Read direction through the same cache: its plan is a separate
        // entry (direction is fingerprinted), and the warm repeat must
        // return the same bytes and times.
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got_ref, rout_ref) =
            run_collective_read_with(&ctx, algo, views.clone(), &file_ref, &mut arena).unwrap();
        let (got_cold, rout_cold) =
            run_collective_read_cached(&ctx, algo, views.clone(), &file_ref, &mut arena, &mut cache)
                .unwrap();
        let (got_warm, rout_warm) =
            run_collective_read_cached(&ctx, algo, views.clone(), &file_ref, &mut arena, &mut cache)
                .unwrap();
        assert_eq!(cache.stats.builds, 2, "{label}: read plan is a distinct entry");
        assert_eq!(cache.stats.hits, 2, "{label}: warm read must hit");
        assert_eq!(got_cold, got_warm, "{label}: warm-hit read bytes differ");
        assert_eq!(got_ref, got_cold, "{label}: cached read bytes differ from uncached");
        for ((r, payload), (_, want)) in got_warm.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "{label}: rank {r} read-back");
        }
        assert_eq!(rout_cold.breakdown, rout_warm.breakdown, "{label}: read breakdown");
        assert_eq!(rout_ref.breakdown, rout_cold.breakdown, "{label}: read vs uncached");
    }
}

/// Plans persist: a second process (modelled by a fresh [`PlanCache`]
/// over the same directory) loads the stored plan instead of building —
/// `disk_loads` counts it, the builder never runs (`build_nanos` stays
/// zero), and execution is identical.
#[test]
fn plans_round_trip_through_the_cache_directory() {
    let fx = Fixture::new();
    let ctx = fx.ctx();
    let ranks = fx.ranks();
    let algo =
        Algorithm::Tam(tamio::coordinator::tam::TamConfig { total_local_aggregators: 4 });
    let dir = scratch_dir("roundtrip");
    let mut arena = ExchangeArena::default();

    let mut cache = PlanCache::with_dir(4, &dir).unwrap();
    let mut file_a = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
    let out_a = run_collective_write_cached(
        &ctx,
        algo,
        ranks.clone(),
        &mut file_a,
        &mut arena,
        &mut cache,
    )
    .unwrap();
    assert_eq!(cache.stats.builds, 1);
    assert_eq!(cache.stats.disk_stores, 1, "fresh build must persist the plan");
    assert!(cache.stats.build_nanos > 0, "cold build must be timed");
    let stored: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "plan"))
        .collect();
    assert_eq!(stored.len(), 1, "exactly one plan file stored");

    // "Next invocation": fresh cache, same directory.
    let mut cache2 = PlanCache::with_dir(4, &dir).unwrap();
    let mut file_b = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
    let out_b = run_collective_write_cached(
        &ctx,
        algo,
        ranks.clone(),
        &mut file_b,
        &mut arena,
        &mut cache2,
    )
    .unwrap();
    // The counters partition: a disk load is neither a hit nor a build.
    assert_eq!(cache2.stats.hits, 0, "memory cache is cold");
    assert_eq!(cache2.stats.builds, 0, "a disk load must not count as a build");
    assert_eq!(cache2.stats.disk_loads, 1, "plan must come from disk");
    assert_eq!(cache2.stats.build_nanos, 0, "builder must not run on a disk load");
    assert_eq!(cache2.stats.rejects, 0);
    assert_eq!(
        image_of(&file_a, &ranks),
        image_of(&file_b, &ranks),
        "disk-loaded plan must execute identically"
    );
    assert_eq!(out_a.breakdown, out_b.breakdown);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt, truncated, or version-bumped plan files are rejected (counted
/// in `rejects`) and the plan is silently rebuilt — a bad cache file can
/// never affect results or crash the run.
#[test]
fn corrupt_or_stale_plan_files_are_rejected_and_rebuilt() {
    let fx = Fixture::new();
    let ctx = fx.ctx();
    let ranks = fx.ranks();
    let algo = Algorithm::TwoPhase;
    let dir = scratch_dir("corrupt");
    let mut arena = ExchangeArena::default();

    let mut cache = PlanCache::with_dir(4, &dir).unwrap();
    let mut file = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
    let out_good = run_collective_write_cached(
        &ctx,
        algo,
        ranks.clone(),
        &mut file,
        &mut arena,
        &mut cache,
    )
    .unwrap();
    let plan_file = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "plan"))
        .expect("stored plan file");
    let pristine = std::fs::read(&plan_file).unwrap();

    let corruptions: Vec<(&str, Vec<u8>)> = vec![
        ("bit-flip in body", {
            let mut b = pristine.clone();
            let mid = 36 + (b.len() - 44) / 2;
            b[mid] ^= 0x01;
            b
        }),
        ("truncated", pristine[..pristine.len() / 2].to_vec()),
        ("future format version", {
            let mut b = pristine.clone();
            b[8..12].copy_from_slice(&(PLAN_FORMAT_VERSION + 1).to_le_bytes());
            b
        }),
        ("empty file", Vec::new()),
    ];
    for (what, bytes) in corruptions {
        std::fs::write(&plan_file, &bytes).unwrap();
        let mut cache = PlanCache::with_dir(4, &dir).unwrap();
        let mut file = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
        let out = run_collective_write_cached(
            &ctx,
            algo,
            ranks.clone(),
            &mut file,
            &mut arena,
            &mut cache,
        )
        .unwrap();
        assert_eq!(cache.stats.rejects, 1, "{what}: must be rejected");
        assert_eq!(cache.stats.disk_loads, 0, "{what}: must not count as a load");
        assert!(cache.stats.build_nanos > 0, "{what}: must rebuild");
        assert_eq!(out.breakdown, out_good.breakdown, "{what}: rebuild must match");
        // The rebuild re-persists a valid file for the next run.
        let mut cache2 = PlanCache::with_dir(4, &dir).unwrap();
        let mut file2 = LustreFile::new(LustreConfig::new(STRIPE, N_OST));
        run_collective_write_cached(
            &ctx,
            algo,
            ranks.clone(),
            &mut file2,
            &mut arena,
            &mut cache2,
        )
        .unwrap();
        assert_eq!(cache2.stats.disk_loads, 1, "{what}: re-persisted plan must load");
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// The decoder's own FNV-1a, so a forged body can carry a *valid*
/// checksum — the hostile length prefix must be caught by bounds
/// arithmetic, not by checksum luck.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Adversarial u64 length fields: prefixes near `u64::MAX` must be
/// rejected by checked arithmetic — never wrap past a bounds test into
/// a panic or a multi-exabyte allocation.
#[test]
fn hostile_u64_length_fields_are_rejected_not_wrapped() {
    use tamio::coordinator::plancache::{
        build_collective_plan, decode_plan, encode_plan, fingerprint_collective,
    };
    let fx = Fixture::new();
    let ctx = fx.ctx();
    let ranks = fx.ranks();
    let views: Vec<(usize, FlatView)> =
        ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
    let cfg = LustreConfig::new(STRIPE, N_OST);
    let fp = fingerprint_collective(
        &ctx,
        &Algorithm::TwoPhase,
        Direction::Write,
        &cfg,
        views.iter().map(|(r, v)| (*r, v)),
    );
    let plan =
        build_collective_plan(&ctx, &Algorithm::TwoPhase, Direction::Write, &views, &cfg, fp)
            .unwrap();
    let good = encode_plan(&plan);
    assert!(decode_plan(&good, fp).is_ok(), "pristine plan must decode");

    // Header body_len: `header + body_len + 8` must not wrap into a
    // passing equality against `bytes.len()`.
    for hostile in [u64::MAX, u64::MAX - 7, u64::MAX - 43, (good.len() as u64).wrapping_neg()] {
        let mut bad = good.clone();
        bad[28..36].copy_from_slice(&hostile.to_le_bytes());
        assert!(decode_plan(&bad, fp).is_err(), "body_len {hostile:#x} must be rejected");
    }

    // Body slice prefix with a RECOMPUTED (valid) checksum: the
    // cursor's `pos + 8 * n` bound must not wrap either.  For a depth-0
    // plan the agg_ranks length prefix sits at body offset 52 (nprocs,
    // level count, and five striping/domain words precede it).
    let header = 36;
    let body_len = good.len() - header - 8;
    for hostile in [u64::MAX, u64::MAX / 8 + 1, (u64::MAX - 51) / 8] {
        let mut bad = good.clone();
        bad[header + 52..header + 60].copy_from_slice(&hostile.to_le_bytes());
        let cks = fnv1a(&bad[header..header + body_len]);
        let end = bad.len();
        bad[end - 8..].copy_from_slice(&cks.to_le_bytes());
        assert!(decode_plan(&bad, fp).is_err(), "slice len {hostile:#x} must be rejected");
    }
}

/// An unusable `--plan-cache` directory fails up front with an
/// actionable error (the CLI surfaces it), not at first store.
#[test]
fn unusable_cache_directory_is_an_actionable_error() {
    let dir = scratch_dir("badpath");
    let blocker = dir.join("not-a-dir");
    std::fs::write(&blocker, b"occupied").unwrap();
    let err = PlanCache::with_dir(4, blocker.join("plans")).unwrap_err().to_string();
    assert!(
        err.contains("plan-cache") && err.contains("not-a-dir"),
        "error must name the flag and the path: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
