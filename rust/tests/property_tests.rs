//! Property tests (propmini harness): random file views, topologies and
//! geometries → structural invariants of the whole pipeline.

use tamio::cluster::{RankPlacement, Topology};
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{run_collective_write, Algorithm};
use tamio::coordinator::filedomain::FileDomains;
use tamio::coordinator::merge::{merge_views, sort_coalesce_pairs, ReqBatch};
use tamio::coordinator::autotune::candidate_specs;
use tamio::coordinator::placement::{
    select_global_aggregators, select_local_aggregators, GlobalPlacement,
};
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::tree::{AggregationPlan, TreeSpec};
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::FlatView;
use tamio::netmodel::NetParams;
use tamio::propmini::{forall, Gen};
use tamio::runtime::engine::NativeEngine;

/// Random sorted view with mixed contiguity.
fn gen_view(g: &mut Gen, max_reqs: usize) -> (FlatView, Vec<u8>) {
    let n = g.usize_in(0, max_reqs);
    let mut cursor = g.u64_below(512);
    let mut pairs = Vec::with_capacity(n);
    for _ in 0..n {
        let len = 1 + g.u64_below(64);
        if !g.bool_with(0.5) {
            cursor += g.u64_below(256);
        }
        pairs.push((cursor, len));
        cursor += len;
    }
    let view = FlatView::from_pairs(pairs).unwrap();
    let total = view.total_bytes();
    let payload: Vec<u8> = (0..total).map(|i| (i as u8).wrapping_mul(31)).collect();
    (view, payload)
}

#[test]
fn prop_sort_coalesce_is_idempotent_and_minimal() {
    forall("coalesce-idempotent", 0xC0A1, 200, |g| {
        let (view, _) = gen_view(g, 60);
        let pairs: Vec<(u64, u64)> = view.iter().collect();
        let once = sort_coalesce_pairs(pairs);
        let twice = sort_coalesce_pairs(once.clone());
        if once != twice {
            return Err(format!("not idempotent: {once:?} vs {twice:?}"));
        }
        // Minimal: no two adjacent outputs contiguous.
        for w in once.windows(2) {
            if w[0].0 + w[0].1 == w[1].0 {
                return Err(format!("not minimal: {:?}", w));
            }
        }
        // Byte-conserving.
        let before: u64 = view.lengths().iter().sum();
        let after: u64 = once.iter().map(|p| p.1).sum();
        if before != after {
            return Err(format!("bytes changed {before} -> {after}"));
        }
        Ok(())
    });
}

#[test]
fn prop_merge_views_equals_sort_coalesce_of_concat() {
    forall("merge-vs-sort", 0x3E46, 150, |g| {
        let k = g.usize_in(1, 8);
        let views: Vec<(FlatView, Vec<u8>)> = (0..k).map(|_| gen_view(g, 30)).collect();
        let refs: Vec<&FlatView> = views.iter().map(|(v, _)| v).collect();
        let merged = merge_views(&refs);
        let concat: Vec<(u64, u64)> = refs.iter().flat_map(|v| v.iter()).collect();
        let want = sort_coalesce_pairs(concat);
        if merged.iter().collect::<Vec<_>>() != want {
            return Err("k-way merge != sort+coalesce".into());
        }
        Ok(())
    });
}

#[test]
fn prop_file_domains_partition_exactly() {
    forall("domains-partition", 0xD0ED, 200, |g| {
        let stripe = 1 + g.u64_below(4096);
        let count = g.usize_in(1, 16);
        let n_agg = g.usize_in(1, 16);
        let lo = g.u64_below(1 << 20);
        let hi = lo + 1 + g.u64_below(1 << 20);
        let d = FileDomains::new(LustreConfig::new(stripe, count), lo, hi, n_agg);
        // Sampled offsets: owned by exactly one (agg, round) slot whose
        // domain contains them.
        for i in 0..50 {
            let off = lo + (hi - lo - 1) * i / 49;
            let a = d.aggregator_of(off);
            let r = d.round_of(off);
            let Some((dlo, dhi)) = d.domain_of(a, r) else {
                return Err(format!("offset {off}: no domain for ({a},{r})"));
            };
            if off < dlo || off >= dhi {
                return Err(format!("offset {off} outside domain [{dlo},{dhi})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_local_aggregator_selection_invariants() {
    forall("local-agg-selection", 0x10CA, 300, |g| {
        let nodes = g.usize_in(1, 8);
        let ppn = g.usize_in(1, 32);
        let c = g.usize_in(1, 40);
        let topo = Topology::new(nodes, ppn);
        let la = select_local_aggregators(&topo, c);
        let expect_per_node = c.clamp(1, ppn);
        if la.ranks.len() != nodes * expect_per_node {
            return Err(format!(
                "count {} != nodes {nodes} * c {expect_per_node}",
                la.ranks.len()
            ));
        }
        for r in 0..topo.nprocs() {
            let a = la.assignment[r];
            if topo.node_of(a) != topo.node_of(r) {
                return Err(format!("rank {r} assigned cross-node aggregator {a}"));
            }
            if a > r {
                return Err(format!("aggregator {a} above member {r}"));
            }
            if !la.ranks.contains(&a) {
                return Err(format!("assignment target {a} not an aggregator"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_global_aggregator_selection_invariants() {
    forall("global-agg-selection", 0x6A6A, 300, |g| {
        let nodes = g.usize_in(1, 10);
        let ppn = g.usize_in(1, 24);
        let n_agg = g.usize_in(1, 64);
        let topo = Topology::new(nodes, ppn);
        let p = topo.nprocs();
        for policy in [GlobalPlacement::Spread, GlobalPlacement::CrayRoundRobin] {
            let agg = select_global_aggregators(&topo, n_agg, policy);
            let expect = n_agg.min(p);
            if agg.len() != expect {
                return Err(format!(
                    "{policy:?}: {} aggregators, expected {expect} (nodes={nodes} ppn={ppn})",
                    agg.len()
                ));
            }
            if agg.iter().any(|&r| r >= p) {
                return Err(format!("{policy:?}: out-of-range rank in {agg:?} (P={p})"));
            }
            let mut uniq = agg.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != agg.len() {
                return Err(format!("{policy:?}: duplicate ranks in {agg:?}"));
            }
            // Spread emits ascending ranks; CrayRoundRobin deliberately
            // interleaves nodes (0, ppn, 1, ppn+1, … — pinned by the
            // paper-example unit test), so only Spread asserts order.
            if policy == GlobalPlacement::Spread && !agg.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("Spread: ranks not ascending: {agg:?}"));
            }
        }
        Ok(())
    });
}

/// The auto-tuner's full candidate grid must produce well-formed trees
/// on every machine shape it can be asked about: the same one-parent-
/// per-rank chain invariants as the random-spec test, but over exactly
/// the specs `--algorithm auto` will price and may execute.
#[test]
fn prop_tuner_grid_chains_keep_one_parent_per_rank() {
    forall("tuner-grid-parents", 0x7D07, 60, |g| {
        let nodes = g.usize_in(1, 6);
        let ppn = g.usize_in(1, 12);
        let spn = g.usize_in(1, ppn.min(4));
        let nps = g.usize_in(0, nodes);
        let placement =
            if g.bool_with(0.5) { RankPlacement::Block } else { RankPlacement::RoundRobin };
        let topo = Topology::hierarchical(nodes, ppn, spn, nps, placement);
        for spec in candidate_specs(&topo) {
            let plan = AggregationPlan::from_spec(&topo, &spec);
            if plan.depth() != spec.depth() {
                return Err(format!(
                    "{spec}: depth {} != spec depth {}",
                    plan.depth(),
                    spec.depth()
                ));
            }
            for rank in 0..topo.nprocs() {
                let chain = plan.parent_chain(rank);
                if chain.len() != plan.depth() {
                    return Err(format!("{spec}: rank {rank} chain length {}", chain.len()));
                }
                let mut rep = rank;
                for (level, &parent) in plan.levels.iter().zip(&chain) {
                    if level.ranks.binary_search(&parent).is_err() {
                        return Err(format!(
                            "{spec}: rank {rank} parent {parent} not a {} aggregator",
                            level.kind
                        ));
                    }
                    if topo.group_of(level.kind, rep) != topo.group_of(level.kind, parent) {
                        return Err(format!(
                            "{spec}: rank {rank} parent {parent} outside its {} group",
                            level.kind
                        ));
                    }
                    if parent > rep {
                        return Err(format!("{spec}: parent {parent} above member {rep}"));
                    }
                    rep = parent;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_aggregation_tree_assigns_one_parent_per_level() {
    forall("tree-parent-invariants", 0x7EE5, 200, |g| {
        let nodes = g.usize_in(1, 8);
        let ppn = g.usize_in(1, 16);
        let spn = g.usize_in(1, ppn.min(4));
        let nps = g.usize_in(0, nodes + 2);
        let placement =
            if g.bool_with(0.5) { RankPlacement::Block } else { RankPlacement::RoundRobin };
        let topo = Topology::hierarchical(nodes, ppn, spn, nps, placement);
        let spec = TreeSpec {
            per_socket: g.usize_in(0, 3),
            per_node: g.usize_in(0, 3),
            per_switch: g.usize_in(0, 2),
        };
        let plan = AggregationPlan::from_spec(&topo, &spec);
        if plan.depth() != spec.depth() {
            return Err(format!("depth {} != spec {}", plan.depth(), spec.depth()));
        }
        // Every rank reaches the top tier through exactly one parent per
        // level; each hop stays inside the level's group, lands on one of
        // that level's aggregators, and never increases the rank.
        for rank in 0..topo.nprocs() {
            let chain = plan.parent_chain(rank);
            if chain.len() != plan.depth() {
                return Err(format!("rank {rank}: chain length {}", chain.len()));
            }
            let mut rep = rank;
            for (level, &parent) in plan.levels.iter().zip(&chain) {
                if level.ranks.binary_search(&parent).is_err() {
                    return Err(format!(
                        "rank {rank}: parent {parent} not a {} aggregator",
                        level.kind
                    ));
                }
                if topo.group_of(level.kind, rep) != topo.group_of(level.kind, parent) {
                    return Err(format!(
                        "rank {rank}: parent {parent} outside its {} group",
                        level.kind
                    ));
                }
                if parent > rep {
                    return Err(format!("rank {rank}: parent {parent} above member {rep}"));
                }
                rep = parent;
            }
        }
        for (li, level) in plan.levels.iter().enumerate() {
            // Aggregator lists are ascending and duplicate-free.
            if !level.ranks.windows(2).all(|w| w[0] < w[1]) {
                return Err(format!("level {li}: ranks not strictly ascending"));
            }
            // Every aggregator serves itself.
            for &a in &level.ranks {
                if level.assignment[a] != a {
                    return Err(format!("level {li}: aggregator {a} not self-assigned"));
                }
            }
            // Members of level ℓ+1 are exactly the aggregators of level ℓ:
            // assignment is defined for them and nothing else.
            let members: Vec<usize> = if li == 0 {
                (0..topo.nprocs()).collect()
            } else {
                plan.levels[li - 1].ranks.clone()
            };
            let assigned = level.assignment.iter().filter(|&&a| a != usize::MAX).count();
            if assigned != members.len() {
                return Err(format!(
                    "level {li}: {assigned} assigned != {} members",
                    members.len()
                ));
            }
            for &m in &members {
                if level.assignment[m] == usize::MAX {
                    return Err(format!("level {li}: member {m} unassigned"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_collective_write_matches_reference_random_everything() {
    forall("collective-vs-reference", 0xF11E, 40, |g| {
        let nodes = 1 + g.usize_in(1, 3);
        let ppn = 1 + g.usize_in(1, 7);
        let topo = Topology::new(nodes, ppn);
        let stripe = 64 + g.u64_below(2048);
        let n_ost = g.usize_in(1, 8);
        let pl = 1 + g.usize_in(0, nodes * ppn);
        // Disjoint per-rank regions to keep the reference order-free.
        let region = 8192u64;
        let mut ranks = Vec::new();
        let mut expected: Vec<(u64, Vec<u8>)> = Vec::new();
        for r in 0..topo.nprocs() {
            let base = r as u64 * region;
            let n = g.usize_in(0, 12);
            let mut cursor = base;
            let mut pairs = Vec::new();
            for _ in 0..n {
                let len = 1 + g.u64_below(100);
                if cursor + len >= base + region {
                    break;
                }
                pairs.push((cursor, len));
                cursor += len + g.u64_below(50);
            }
            let view = FlatView::from_pairs(pairs).unwrap();
            let total = view.total_bytes();
            let payload: Vec<u8> =
                (0..total).map(|i| (i as u8) ^ (r as u8)).collect();
            let mut cursor_b = 0usize;
            for (off, len) in view.iter() {
                expected.push((off, payload[cursor_b..cursor_b + len as usize].to_vec()));
                cursor_b += len as usize;
            }
            ranks.push((r, ReqBatch::new(view, payload)));
        }
        let net = NetParams::default();
        let cpu = CpuModel::default();
        let io = IoModel::default();
        let eng = NativeEngine;
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: n_ost,
        };
        let mut file = LustreFile::new(LustreConfig::new(stripe, n_ost));
        let algo = Algorithm::Tam(TamConfig { total_local_aggregators: pl });
        run_collective_write(&ctx, algo, ranks, &mut file)
            .map_err(|e| format!("write failed: {e}"))?;
        for (off, bytes) in expected {
            let got = file.read_at(off, bytes.len() as u64);
            if got != bytes {
                return Err(format!("mismatch at offset {off}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stripe_split_conserves_bytes_and_osts() {
    forall("stripe-split", 0x57A1, 300, |g| {
        let cfg = LustreConfig::new(1 + g.u64_below(4096), g.usize_in(1, 12));
        let off = g.u64_below(1 << 30);
        let len = g.u64_below(1 << 16);
        let pieces = cfg.split_by_stripe(off, len);
        let total: u64 = pieces.iter().map(|p| p.2).sum();
        if total != len {
            return Err(format!("bytes {total} != {len}"));
        }
        let mut cursor = off;
        for (ost, poff, plen) in pieces {
            if poff != cursor {
                return Err(format!("gap at {poff} (expected {cursor})"));
            }
            if cfg.ost_of(poff) != ost {
                return Err("wrong OST".into());
            }
            if plen == 0 {
                return Err("zero-length piece".into());
            }
            cursor = poff + plen;
        }
        Ok(())
    });
}
