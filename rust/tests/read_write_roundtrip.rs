//! Cross-algorithm round-trip property suite: for random `FlatView`
//! patterns, `run_collective_write` followed by `run_collective_read`
//! returns bit-identical payloads under **both** `Algorithm::TwoPhase` and
//! `Algorithm::Tam`, across 1/4/16 global aggregators, several local
//! aggregator counts, and stripe geometries chosen so requests straddle
//! stripe boundaries.  This suite locks in the streaming read path (round
//! loop, scratch arenas, engine merges, vectored reads, reply assembly)
//! against the byte-accurate storage model.

use tamio::cluster::Topology;
use tamio::config::RunConfig;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read, run_collective_write, Algorithm, Direction, DirectionSpec,
};
use tamio::coordinator::merge::ReqBatch;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::experiments::run_once;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;
use tamio::util::SplitMix64;
use tamio::workloads::WorkloadKind;

struct Fx {
    topo: Topology,
    net: NetParams,
    cpu: CpuModel,
    io: IoModel,
    eng: NativeEngine,
}

impl Fx {
    fn new(nodes: usize, ppn: usize) -> Self {
        Fx {
            topo: Topology::new(nodes, ppn),
            net: NetParams::default(),
            cpu: CpuModel::default(),
            io: IoModel::default(),
            eng: NativeEngine,
        }
    }

    fn ctx(&self, n_agg: usize) -> CollectiveCtx<'_> {
        CollectiveCtx {
            topo: &self.topo,
            net: &self.net,
            cpu: &self.cpu,
            io: &self.io,
            engine: &self.eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: n_agg,
        }
    }
}

/// Deal one global ascending request sequence to the ranks at random:
/// views are disjoint in file space (so the written image is well-defined)
/// but interleave arbitrarily, with random gaps, zero-length requests, and
/// lengths up to ~2.5 stripes so many requests straddle stripe boundaries.
fn random_disjoint_ranks(
    rng: &mut SplitMix64,
    nprocs: usize,
    total_reqs: usize,
    stripe: u64,
    seed: u64,
) -> Vec<(usize, ReqBatch)> {
    let mut per_rank: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nprocs];
    let mut cursor = rng.gen_range(stripe.max(2)); // may start mid-stripe
    for _ in 0..total_reqs {
        let r = rng.gen_range(nprocs as u64) as usize;
        if rng.gen_bool(0.4) {
            cursor += rng.gen_range(2 * stripe);
        }
        let len = match rng.gen_range(5) {
            0 => 0,                                // zero-length request
            1 => {
                // Park on the last byte of a stripe: a 2-byte request
                // straddles the boundary.
                cursor = (cursor / stripe + 1) * stripe - 1;
                2
            }
            2 => 1 + rng.gen_range(5 * stripe / 2), // up to ~2.5 stripes
            _ => 1 + rng.gen_range(stripe / 2),
        };
        per_rank[r].push((cursor, len));
        cursor += len;
    }
    per_rank
        .into_iter()
        .enumerate()
        .map(|(r, pairs)| {
            let view = FlatView::from_pairs(pairs).unwrap();
            let payload = deterministic_payload(seed, r, view.total_bytes());
            (r, ReqBatch::new(view, payload))
        })
        .collect()
}

fn check_roundtrip(
    fx: &Fx,
    n_agg: usize,
    stripe_count: usize,
    stripe: u64,
    ranks: &[(usize, ReqBatch)],
    write_algo: Algorithm,
    read_algos: &[Algorithm],
) {
    let ctx = fx.ctx(n_agg);
    let mut file = LustreFile::new(LustreConfig::new(stripe, stripe_count));
    run_collective_write(&ctx, write_algo, ranks.to_vec(), &mut file)
        .unwrap_or_else(|e| panic!("write {} failed: {e}", write_algo.name()));
    for &read_algo in read_algos {
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) = run_collective_read(&ctx, read_algo, views, &file)
            .unwrap_or_else(|e| panic!("read {} failed: {e}", read_algo.name()));
        assert_eq!(got.len(), ranks.len());
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(
                payload,
                &want.payload,
                "rank {r}: write={} read={} n_agg={n_agg} stripe={stripe} mismatch",
                write_algo.name(),
                read_algo.name()
            );
        }
        assert_eq!(
            outcome.counters.bytes,
            ranks.iter().map(|(_, b)| b.view.total_bytes()).sum::<u64>()
        );
    }
}

#[test]
fn roundtrip_across_algorithms_aggregators_and_stripes() {
    let mut rng = SplitMix64::new(0x07_2170);
    let fx = Fx::new(2, 8); // 16 ranks on 2 nodes
    for &n_agg in &[1usize, 4, 16] {
        for &(stripe, stripe_count) in &[(64u64, 4usize), (100, 3)] {
            for case in 0..3u64 {
                let seed = 0x5EED ^ ((n_agg as u64) << 8) ^ (stripe << 16) ^ case;
                let ranks = random_disjoint_ranks(&mut rng, fx.topo.nprocs(), 150, stripe, seed);
                let algos = [
                    Algorithm::TwoPhase,
                    Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
                ];
                for write_algo in algos {
                    check_roundtrip(
                        &fx,
                        n_agg,
                        stripe_count,
                        stripe,
                        &ranks,
                        write_algo,
                        &algos,
                    );
                }
            }
        }
    }
}

#[test]
fn roundtrip_sweeps_local_aggregator_counts() {
    // P_L from 2 (one per node) through P (degenerate TAM == two-phase).
    let mut rng = SplitMix64::new(0x9000_00B0);
    let fx = Fx::new(2, 8);
    let ranks = random_disjoint_ranks(&mut rng, fx.topo.nprocs(), 200, 64, 0xFACE);
    for pl in [2usize, 4, 8, 16] {
        let tam = Algorithm::Tam(TamConfig { total_local_aggregators: pl });
        check_roundtrip(&fx, 4, 4, 64, &ranks, tam, &[tam, Algorithm::TwoPhase]);
    }
}

#[test]
fn roundtrip_uneven_topology_and_single_aggregator() {
    // 3 nodes × 5 ppn with P_L = 7: nothing divides anything.
    let mut rng = SplitMix64::new(0xDD31);
    let fx = Fx::new(3, 5);
    let ranks = random_disjoint_ranks(&mut rng, fx.topo.nprocs(), 120, 100, 0xBEE);
    let tam = Algorithm::Tam(TamConfig { total_local_aggregators: 7 });
    check_roundtrip(&fx, 1, 3, 100, &ranks, Algorithm::TwoPhase, &[Algorithm::TwoPhase, tam]);
    check_roundtrip(&fx, 1, 3, 100, &ranks, tam, &[tam]);
}

#[test]
fn roundtrip_through_run_once_driver() {
    // Exercise the config→driver→coordinator plumbing rather than calling
    // the coordinator directly: `--direction both` through
    // `experiments::run_once` must produce a verified write and a verified
    // read for both algorithms, driven off the same RunConfig.
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 4;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 12, 4);
    cfg.verify = true;
    cfg.direction = DirectionSpec::Both;
    for algo in [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
    ] {
        cfg.algorithm = algo;
        let results = run_once(&cfg).unwrap();
        assert_eq!(results.len(), 2, "{}", algo.name());
        for ((run, verify), want_dir) in
            results.iter().zip([Direction::Write, Direction::Read])
        {
            assert_eq!(run.direction, want_dir, "{}", algo.name());
            let v = verify
                .as_ref()
                .unwrap_or_else(|| panic!("{} [{}] missing verify", run.label, run.direction));
            assert!(
                v.passed(),
                "{} [{}]: {}/{} ranks",
                run.label,
                run.direction,
                v.ok,
                v.total
            );
            assert!(run.breakdown.total() > 0.0);
            assert!(run.counters.bytes > 0);
        }
        // One exchange engine: both directions ran the same round count.
        assert_eq!(results[0].0.counters.rounds, results[1].0.counters.rounds);
    }
}

/// §Acceptance: a depth-0 `tree:` plan is bit-identical to `TwoPhase` and
/// a depth-1 node plan is bit-identical to `Tam` — file contents, verify
/// pass, message counts, and the full simulated breakdown, in both
/// directions.
///
/// What this pins, precisely: for writes, `TwoPhase` runs
/// `two_phase_write` (no tree fold) while `Tree(flat)` runs the tree
/// pipeline — two distinct paths.  For TAM, both sides share the tree
/// pipeline, so the assertion pins the `tree:node=c` spec → plan mapping
/// against `for_tam`'s `P_L` distribution.  Equivalence to the
/// *pre-refactor* implementations is carried by the pre-existing 2P/TAM
/// suites (reference images, counters, structural identities), whose
/// expected values were written against the old code and kept unchanged.
#[test]
fn tree_depth0_and_depth1_bitwise_match_two_phase_and_tam() {
    let mut rng = SplitMix64::new(0x7EE_B17);
    let fx = Fx::new(2, 8);
    let ctx = fx.ctx(4);
    let ranks = random_disjoint_ranks(&mut rng, fx.topo.nprocs(), 180, 64, 0x1D);
    let views: Vec<(usize, FlatView)> =
        ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
    // (reference algorithm, equivalent tree plan): depth 0 vs two-phase,
    // depth 1 (2 aggregators per node = P_L 4 over 2 nodes) vs TAM.
    let pairs = [
        (Algorithm::TwoPhase, "flat".parse().unwrap()),
        (
            Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
            "node=2".parse().unwrap(),
        ),
    ];
    for (reference, spec) in pairs {
        let tree = Algorithm::Tree(spec);
        // ---- write direction.
        let mut f_ref = LustreFile::new(LustreConfig::new(64, 4));
        let mut f_tree = LustreFile::new(LustreConfig::new(64, 4));
        let ref_out =
            run_collective_write(&ctx, reference, ranks.clone(), &mut f_ref).unwrap();
        let tree_out =
            run_collective_write(&ctx, tree, ranks.clone(), &mut f_tree).unwrap();
        let hi = ranks.iter().filter_map(|(_, b)| b.view.max_end()).max().unwrap();
        assert_eq!(
            f_ref.read_at(0, hi),
            f_tree.read_at(0, hi),
            "{}: file contents differ",
            reference.name()
        );
        assert_eq!(ref_out.counters.msgs_intra, tree_out.counters.msgs_intra);
        assert_eq!(ref_out.counters.msgs_inter, tree_out.counters.msgs_inter);
        assert_eq!(ref_out.counters.rounds, tree_out.counters.rounds);
        assert_eq!(ref_out.counters.max_in_degree, tree_out.counters.max_in_degree);
        assert_eq!(ref_out.counters.reqs_posted, tree_out.counters.reqs_posted);
        assert_eq!(ref_out.counters.reqs_after_intra, tree_out.counters.reqs_after_intra);
        assert_eq!(ref_out.counters.reqs_at_io, tree_out.counters.reqs_at_io);
        assert_eq!(ref_out.breakdown.intra_comm, tree_out.breakdown.intra_comm);
        assert_eq!(ref_out.breakdown.intra_sort, tree_out.breakdown.intra_sort);
        assert_eq!(ref_out.breakdown.intra_memcpy, tree_out.breakdown.intra_memcpy);
        assert_eq!(ref_out.breakdown.inter_comm, tree_out.breakdown.inter_comm);
        assert_eq!(ref_out.breakdown.inter_sort, tree_out.breakdown.inter_sort);
        assert_eq!(ref_out.breakdown.io_phase, tree_out.breakdown.io_phase);
        assert_eq!(ref_out.breakdown.total(), tree_out.breakdown.total());
        // ---- read direction.
        let (ref_got, ref_read) =
            run_collective_read(&ctx, reference, views.clone(), &f_ref).unwrap();
        let (tree_got, tree_read) =
            run_collective_read(&ctx, tree, views.clone(), &f_tree).unwrap();
        assert_eq!(ref_got, tree_got, "{}: read payloads differ", reference.name());
        for ((r, payload), (_, want)) in ref_got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} reference read-back");
        }
        assert_eq!(ref_read.counters.msgs_intra, tree_read.counters.msgs_intra);
        assert_eq!(ref_read.counters.msgs_inter, tree_read.counters.msgs_inter);
        assert_eq!(ref_read.counters.rounds, tree_read.counters.rounds);
        assert_eq!(ref_read.breakdown.total(), tree_read.breakdown.total());
    }
}

/// §Acceptance: a depth-2 (socket + node) plan on a hierarchical topology
/// round-trips end-to-end in both directions, through the public
/// config-driven driver as well as the coordinator API.
#[test]
fn tree_depth2_round_trips_on_hierarchical_topology() {
    use tamio::cluster::RankPlacement;
    let mut rng = SplitMix64::new(0xDEE9_2);
    let fx = Fx {
        topo: Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block),
        net: NetParams::default(),
        cpu: CpuModel::default(),
        io: IoModel::default(),
        eng: NativeEngine,
    };
    let ranks = random_disjoint_ranks(&mut rng, fx.topo.nprocs(), 160, 64, 0xD2);
    let tree = Algorithm::Tree("socket=2,node=1".parse().unwrap());
    check_roundtrip(&fx, 4, 4, 64, &ranks, tree, &[tree, Algorithm::TwoPhase]);
    check_roundtrip(&fx, 4, 4, 64, &ranks, Algorithm::TwoPhase, &[tree]);

    // Driver plumbing: config keys → hierarchical topology → verified
    // write and read panels.
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 8;
    cfg.sockets_per_node = 2;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 12, 4);
    cfg.verify = true;
    cfg.direction = DirectionSpec::Both;
    cfg.algorithm = Algorithm::Tree("socket=2,node=1".parse().unwrap());
    let results = run_once(&cfg).unwrap();
    assert_eq!(results.len(), 2);
    for (run, verify) in &results {
        let v = verify.as_ref().expect("tree runs verify");
        assert!(v.passed(), "{} [{}]: {}/{}", run.label, run.direction, v.ok, v.total);
        assert_eq!(run.breakdown.levels.len(), 2, "[{}]", run.direction);
        assert_eq!(run.breakdown.levels[0].label, "socket");
        assert_eq!(run.breakdown.levels[1].label, "node");
    }
}

#[test]
fn roundtrip_with_empty_and_zero_length_ranks() {
    let fx = Fx::new(2, 4);
    // Rank 0 writes one stripe-misaligned extent; rank 3 writes two pieces
    // straddling a boundary; others post empty or zero-length views.
    let v0 = FlatView::from_pairs(vec![(10, 100)]).unwrap();
    let v3 = FlatView::from_pairs(vec![(200, 30), (254, 20)]).unwrap();
    let ranks: Vec<(usize, ReqBatch)> = (0..fx.topo.nprocs())
        .map(|r| match r {
            0 => (r, ReqBatch::new(v0.clone(), deterministic_payload(1, 0, 100))),
            3 => (r, ReqBatch::new(v3.clone(), deterministic_payload(1, 3, 50))),
            _ if r % 2 == 0 => (r, ReqBatch::new(FlatView::empty(), Vec::new())),
            _ => (r, ReqBatch::new(FlatView::from_pairs(vec![(64, 0)]).unwrap(), Vec::new())),
        })
        .collect();
    let algos = [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 2 }),
    ];
    for write_algo in algos {
        check_roundtrip(&fx, 4, 4, 64, &ranks, write_algo, &algos);
    }
}
