//! Determinism matrix for the worker-pool runtime (§Perf tentpole): the
//! collective's results must be **bit-identical** for every pool width.
//! Workers steal `(level, aggregator, round)` tasks in whatever order the
//! scheduler produces, but each task writes a pre-assigned slot, so the
//! observable outputs — file images, read-back payloads, counters, and
//! the simulated breakdown — may not depend on the width.
//!
//! Widths 1/2/3 are pinned per-test via `with_runtime` overrides; the
//! `None` column uses the process-global pool (whatever `TAMIO_THREADS` /
//! `available_parallelism()` resolves to).  The remaining two matrix axes
//! run in CI rather than in-process: `scripts/check.sh` re-runs this
//! whole suite under `TAMIO_THREADS=1` (global-pool serial leg) and,
//! when the toolchain supports `portable_simd`, under `--features simd`
//! (the SIMD kernels must reproduce the scalar results exactly — the
//! same assertions below then pin the lane-parallel path).

use tamio::cluster::{RankPlacement, Topology};
use tamio::config::RunConfig;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read, run_collective_read_with, run_collective_write,
    run_collective_write_with, Algorithm, DirectionSpec, ExchangeArena, OverlapMode,
};
use tamio::coordinator::merge::ReqBatch;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::experiments::run_once;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;
use tamio::util::runtime::{with_runtime, Runtime};
use tamio::util::SplitMix64;
use tamio::workloads::WorkloadKind;

struct Fx {
    topo: Topology,
    net: NetParams,
    cpu: CpuModel,
    io: IoModel,
    eng: NativeEngine,
}

impl Fx {
    fn flat(nodes: usize, ppn: usize) -> Self {
        Fx {
            topo: Topology::new(nodes, ppn),
            net: NetParams::default(),
            cpu: CpuModel::default(),
            io: IoModel::default(),
            eng: NativeEngine,
        }
    }

    fn ctx(&self, n_agg: usize) -> CollectiveCtx<'_> {
        CollectiveCtx {
            topo: &self.topo,
            net: &self.net,
            cpu: &self.cpu,
            io: &self.io,
            engine: &self.eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: n_agg,
        }
    }
}

/// Random disjoint-in-file rank views: interleaved, gappy, with
/// zero-length requests and stripe-straddling lengths (same shape family
/// as the round-trip suite, so the pool sees realistic merge work).
fn random_ranks(
    rng: &mut SplitMix64,
    nprocs: usize,
    total_reqs: usize,
    stripe: u64,
    seed: u64,
) -> Vec<(usize, ReqBatch)> {
    let mut per_rank: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nprocs];
    let mut cursor = rng.gen_range(stripe.max(2));
    for _ in 0..total_reqs {
        let r = rng.gen_range(nprocs as u64) as usize;
        if rng.gen_bool(0.35) {
            cursor += rng.gen_range(2 * stripe);
        }
        let len = match rng.gen_range(4) {
            0 => 0,
            1 => 1 + rng.gen_range(5 * stripe / 2),
            _ => 1 + rng.gen_range(stripe / 2),
        };
        per_rank[r].push((cursor, len));
        cursor += len;
    }
    per_rank
        .into_iter()
        .enumerate()
        .map(|(r, pairs)| {
            let view = FlatView::from_pairs(pairs).unwrap();
            let payload = deterministic_payload(seed, r, view.total_bytes());
            (r, ReqBatch::new(view, payload))
        })
        .collect()
}

/// Everything a width could possibly perturb, flattened for `assert_eq`.
#[derive(Debug, PartialEq)]
struct Digest {
    file_image: Vec<u8>,
    read_payloads: Vec<(usize, Vec<u8>)>,
    write_counters: (usize, usize, u64, usize, u64, u64, u64, u64),
    read_counters: (usize, usize, u64, usize),
    write_total: f64,
    read_total: f64,
}

/// Run one write+read collective at the given pool width (`None` = the
/// process-global pool) and digest every observable output.
fn digest_at_width(
    fx: &Fx,
    algo: Algorithm,
    ranks: &[(usize, ReqBatch)],
    width: Option<usize>,
) -> Digest {
    let body = || {
        let ctx = fx.ctx(4);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let wout = run_collective_write(&ctx, algo, ranks.to_vec(), &mut file)
            .unwrap_or_else(|e| panic!("write {} failed: {e}", algo.name()));
        let hi = ranks.iter().filter_map(|(_, b)| b.view.max_end()).max().unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, rout) = run_collective_read(&ctx, algo, views, &file)
            .unwrap_or_else(|e| panic!("read {} failed: {e}", algo.name()));
        let wc = &wout.counters;
        let rc = &rout.counters;
        Digest {
            file_image: file.read_at(0, hi),
            read_payloads: got,
            write_counters: (
                wc.msgs_intra,
                wc.msgs_inter,
                wc.rounds,
                wc.max_in_degree,
                wc.bytes,
                wc.reqs_posted,
                wc.reqs_after_intra,
                wc.reqs_at_io,
            ),
            read_counters: (rc.msgs_intra, rc.msgs_inter, rc.rounds, rc.max_in_degree),
            write_total: wout.breakdown.total(),
            read_total: rout.breakdown.total(),
        }
    };
    match width {
        Some(w) => with_runtime(&Runtime::new(w), body),
        None => body(),
    }
}

/// §Acceptance: serial (width 1), pooled (2/3), and default-width runs
/// are bit-identical for two-phase, TAM, and tree plans, both directions.
#[test]
fn roundtrip_is_bit_identical_across_pool_widths() {
    let mut rng = SplitMix64::new(0x0DE7_E12);
    let fx = Fx::flat(2, 8);
    let algos = [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        Algorithm::Tree("node=2".parse().unwrap()),
    ];
    for (case, algo) in algos.into_iter().enumerate() {
        let ranks =
            random_ranks(&mut rng, fx.topo.nprocs(), 150, 64, 0xA0 + case as u64);
        let baseline = digest_at_width(&fx, algo, &ranks, Some(1));
        // The serial width must reproduce the rank payloads exactly
        // before it is promoted to the reference for wider pools.
        for ((r, payload), (_, want)) in baseline.read_payloads.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "{}: rank {r} read-back", algo.name());
        }
        for width in [Some(2), Some(3), None] {
            let got = digest_at_width(&fx, algo, &ranks, width);
            assert_eq!(
                got,
                baseline,
                "{} at width {width:?} diverged from serial",
                algo.name()
            );
        }
    }
}

/// Depth-2 tree plans on a hierarchical topology push tasks through every
/// level of the aggregation pipeline (socket gather, node gather, down
/// scatter); the width matrix must hold there too.
#[test]
fn hierarchical_tree_is_bit_identical_across_pool_widths() {
    let mut rng = SplitMix64::new(0x5_0C4E7);
    let fx = Fx {
        topo: Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block),
        net: NetParams::default(),
        cpu: CpuModel::default(),
        io: IoModel::default(),
        eng: NativeEngine,
    };
    let ranks = random_ranks(&mut rng, fx.topo.nprocs(), 160, 64, 0x7E);
    let algo = Algorithm::Tree("socket=2,node=1".parse().unwrap());
    let baseline = digest_at_width(&fx, algo, &ranks, Some(1));
    for width in [Some(2), Some(3), None] {
        let got = digest_at_width(&fx, algo, &ranks, width);
        assert_eq!(got, baseline, "tree depth-2 at width {width:?} diverged");
    }
}

/// Like [`Digest`], but for the overlap matrix: the breakdown is kept as
/// raw component rows *minus* the `overlap_saved` credit, so a pipelined
/// run digests bit-identically to the serial one (pipelining reorders
/// the schedule, never the bytes or the per-phase charges).
#[derive(Debug, PartialEq)]
struct PipeDigest {
    file_image: Vec<u8>,
    read_payloads: Vec<(usize, Vec<u8>)>,
    write_counters: (usize, usize, u64, usize, u64, u64, u64, u64),
    read_counters: (usize, usize, u64, usize),
    write_rows: Vec<(&'static str, f64)>,
    read_rows: Vec<(&'static str, f64)>,
}

/// Run one write+read collective through arenas pinned to `overlap` at
/// the given pool width; returns the digest plus the write/read
/// `overlap_saved` credits (excluded from the digest, asserted apart).
fn digest_overlap(
    fx: &Fx,
    algo: Algorithm,
    ranks: &[(usize, ReqBatch)],
    width: Option<usize>,
    overlap: OverlapMode,
) -> (PipeDigest, f64, f64) {
    let body = || {
        let ctx = fx.ctx(4);
        let mut arena = ExchangeArena::default();
        arena.overlap = overlap;
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let wout =
            run_collective_write_with(&ctx, algo, ranks.to_vec(), &mut file, &mut arena)
                .unwrap_or_else(|e| panic!("write {} failed: {e}", algo.name()));
        let hi = ranks.iter().filter_map(|(_, b)| b.view.max_end()).max().unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, rout) = run_collective_read_with(&ctx, algo, views, &file, &mut arena)
            .unwrap_or_else(|e| panic!("read {} failed: {e}", algo.name()));
        let wc = &wout.counters;
        let rc = &rout.counters;
        let rows = |b: &tamio::coordinator::breakdown::Breakdown| {
            b.rows().into_iter().filter(|(n, _)| *n != "overlap_saved").collect::<Vec<_>>()
        };
        let digest = PipeDigest {
            file_image: file.read_at(0, hi),
            read_payloads: got,
            write_counters: (
                wc.msgs_intra,
                wc.msgs_inter,
                wc.rounds,
                wc.max_in_degree,
                wc.bytes,
                wc.reqs_posted,
                wc.reqs_after_intra,
                wc.reqs_at_io,
            ),
            read_counters: (rc.msgs_intra, rc.msgs_inter, rc.rounds, rc.max_in_degree),
            write_rows: rows(&wout.breakdown),
            read_rows: rows(&rout.breakdown),
        };
        (digest, wout.breakdown.overlap_saved, rout.breakdown.overlap_saved)
    };
    match width {
        Some(w) => with_runtime(&Runtime::new(w), body),
        None => body(),
    }
}

/// §Tentpole acceptance: `--overlap on` must be a pure schedule change —
/// file bytes, gathered payloads, counters, and every per-phase charge
/// bit-identical to the serial loop at any pool width; only the
/// `overlap_saved` credit (and therefore the total) differs, and on
/// multi-round exchanges it must actually be earned.
#[test]
fn pipelined_roundtrip_is_bit_identical_to_serial_across_widths() {
    let mut rng = SplitMix64::new(0x07E1_4AB);
    let fx = Fx::flat(2, 8);
    let algos = [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
    ];
    for (case, algo) in algos.into_iter().enumerate() {
        let ranks =
            random_ranks(&mut rng, fx.topo.nprocs(), 150, 64, 0xB0 + case as u64);
        let (serial, s_ws, s_rs) =
            digest_overlap(&fx, algo, &ranks, Some(1), OverlapMode::Off);
        assert_eq!((s_ws, s_rs), (0.0, 0.0), "{}: serial runs earn no credit", algo.name());
        for ((r, payload), (_, want)) in serial.read_payloads.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "{}: rank {r} read-back", algo.name());
        }
        for width in [Some(1), Some(2), None] {
            let (piped, ws, rs) = digest_overlap(&fx, algo, &ranks, width, OverlapMode::On);
            assert_eq!(
                piped,
                serial,
                "{} pipelined at width {width:?} diverged from serial",
                algo.name()
            );
            let rounds = piped.write_counters.2;
            if rounds >= 2 {
                assert!(ws > 0.0, "{} [{width:?}]: write credit missing", algo.name());
                assert!(rs > 0.0, "{} [{width:?}]: read credit missing", algo.name());
            }
        }
    }
}

/// The overlap matrix on a depth-2 aggregation tree: level folds feed the
/// same double-buffered exchange, so the pipelined digests must match the
/// serial one there too.
#[test]
fn pipelined_hierarchical_tree_matches_serial_across_widths() {
    let mut rng = SplitMix64::new(0x0517_EE7);
    let fx = Fx {
        topo: Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block),
        net: NetParams::default(),
        cpu: CpuModel::default(),
        io: IoModel::default(),
        eng: NativeEngine,
    };
    let ranks = random_ranks(&mut rng, fx.topo.nprocs(), 160, 64, 0x9C);
    let algo = Algorithm::Tree("socket=2,node=1".parse().unwrap());
    let (serial, _, _) = digest_overlap(&fx, algo, &ranks, Some(1), OverlapMode::Off);
    for width in [Some(1), Some(2), None] {
        let (piped, ws, _) = digest_overlap(&fx, algo, &ranks, width, OverlapMode::On);
        assert_eq!(piped, serial, "tree depth-2 pipelined at width {width:?} diverged");
        if piped.write_counters.2 >= 2 {
            assert!(ws > 0.0, "[{width:?}]: tree write credit missing");
        }
    }
}

/// Degraded mode through the pipeline: a transient-OST retry in round r
/// must not corrupt round r+1's already-staged bank, and the retry/
/// backoff accounting must match the serial run exactly (backoff is
/// synchronization the pipeline can never hide).
#[test]
fn pipelined_degraded_runs_match_serial_and_still_retry() {
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 4;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 12, 4);
    cfg.verify = true;
    cfg.direction = DirectionSpec::Both;
    cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
    // OST 0 backs the first stripe, so the countdown fires on the first
    // touch of either direction.
    cfg.faults = Some("ost_fail=0@transient:2".parse().unwrap());
    cfg.fault_seed = 42;
    let run = |w: usize, overlap: OverlapMode| {
        with_runtime(&Runtime::new(w), || {
            let mut c = cfg.clone();
            c.overlap = overlap;
            run_once(&c)
                .unwrap()
                .into_iter()
                .map(|(run, verify)| {
                    let v = verify.expect("verify requested");
                    assert!(v.passed(), "width {w} {overlap}: {}/{} ranks", v.ok, v.total);
                    (
                        run.direction,
                        run.counters.bytes,
                        run.counters.rounds,
                        run.counters.retries,
                        run.counters.backoff_units,
                        run.breakdown.io_phase,
                    )
                })
                .collect::<Vec<_>>()
        })
    };
    let serial = run(1, OverlapMode::Off);
    assert!(
        serial.iter().any(|t| t.3 > 0),
        "the transient fault must cost retries: {serial:?}"
    );
    for w in [1, 2] {
        assert_eq!(run(w, OverlapMode::On), serial, "width {w} degraded pipeline diverged");
    }
}

/// The config→driver plumbing (`experiments::run_once`, plan build,
/// verify) is also width-invariant: identical verified results and
/// simulated times at widths 1 and 3.
#[test]
fn driver_results_are_width_invariant() {
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 4;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 12, 4);
    cfg.verify = true;
    cfg.direction = DirectionSpec::Both;
    cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });

    let run = |w: usize| {
        with_runtime(&Runtime::new(w), || {
            let results = run_once(&cfg).unwrap();
            assert_eq!(results.len(), 2);
            results
                .into_iter()
                .map(|(run, verify)| {
                    let v = verify.expect("verify requested");
                    assert!(v.passed(), "width {w}: {}/{} ranks", v.ok, v.total);
                    (run.direction, run.counters.bytes, run.counters.rounds, run.breakdown.total())
                })
                .collect::<Vec<_>>()
        })
    };
    assert_eq!(run(1), run(3), "driver results depend on pool width");
}
