#!/usr/bin/env bash
# Repo gate: tier-1 (release build + tests) plus formatting and lints.
#
#   scripts/check.sh            # tier-1 + fmt + clippy
#   BENCH=1 scripts/check.sh    # additionally regenerate BENCH_hotpath.json
#   SCALE=1 scripts/check.sh    # additionally smoke the paper's 16384-rank
#                               # point (verification-gated sweep, ~minutes)
#
# fmt/clippy are skipped with a warning when the components are not
# installed (the offline image ships a bare toolchain).  Set
# REQUIRE_LINT=1 (CI does) to turn those skips into hard failures so a
# runner that silently lost its components cannot green-light unlinted
# code.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
test_log="$(mktemp)"
trap 'rm -f "$test_log"' EXIT
cargo test -q 2>&1 | tee "$test_log"
# No silently-skipped tests: the only sanctioned skips are the xla-gated
# tests, which print "[skip] ..." and still PASS.  A nonzero `ignored`
# count means a test dropped out of the suite (e.g. a rotting read-path
# test) without anyone noticing — fail loudly instead.
if grep -E '(^|[^0-9])[1-9][0-9]* ignored' "$test_log" >/dev/null; then
    echo "check.sh: FAIL — ignored tests detected; only xla-gated [skip] passes may skip" >&2
    exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
elif [ "${REQUIRE_LINT:-0}" = "1" ]; then
    echo "check.sh: FAIL — REQUIRE_LINT=1 but rustfmt is not installed" >&2
    exit 1
else
    echo "warn: rustfmt not installed; skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
elif [ "${REQUIRE_LINT:-0}" = "1" ]; then
    echo "check.sh: FAIL — REQUIRE_LINT=1 but clippy is not installed" >&2
    exit 1
else
    echo "warn: clippy not installed; skipping" >&2
fi

# Benches are harness = false and excluded from `cargo test`; compile
# them unconditionally so bench-only breakage is caught in tier-1 even
# when BENCH=1 is not set.  The depth-ablation and auto-tune benches are
# named explicitly so a target-list regression in Cargo.toml cannot
# silently drop them.
echo "== cargo bench --no-run (bench compile gate) =="
cargo bench --no-run
cargo bench --no-run --bench ablation_depth
cargo bench --no-run --bench ablation_autotune

if [ "${BENCH:-0}" = "1" ]; then
    echo "== hot-path bench (writes BENCH_hotpath.json) =="
    cargo bench --bench hotpath
fi

if [ "${SCALE:-0}" = "1" ]; then
    # The ROADMAP scale-sweep item: a small sweep at 16384 ranks on 256
    # nodes, both directions.  E3SM-G at scale 1024 keeps it ~170k
    # requests / ~89 MiB.  Write bars verify by vectored read-back
    # (--verify), read bars always verify the gathered bytes; any
    # mismatch fails the sweep (nonzero exit) and therefore this gate.
    echo "== SCALE=1: 16384-rank / 256-node sweep smoke (both directions) =="
    cargo run --release --bin tamio -- sweep \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --pl 256 --direction both --verify
    # Depth-2 aggregation tree at the same point on the hierarchical
    # topology (4 sockets/node, 16 nodes/switch): write bars verify by
    # vectored read-back, read bars always verify the gathered bytes.
    echo "== SCALE=1: depth-2 tree at 16384 ranks (both directions) =="
    cargo run --release --bin tamio -- run \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --sockets_per_node 4 --nodes_per_switch 16 \
        --algorithm tree:socket=4,node=2 --direction both --verify
fi

echo "check.sh: all gates passed"
