#!/usr/bin/env bash
# Repo gate: tier-1 (release build + tests) plus formatting and lints.
#
#   scripts/check.sh            # tier-1 + fmt + clippy
#   BENCH=1 scripts/check.sh    # additionally regenerate BENCH_hotpath.json
#   SCALE=1 scripts/check.sh    # additionally smoke the paper's 16384-rank
#                               # point (verification-gated sweep, ~minutes)
#   FAULTS=1 scripts/check.sh   # additionally smoke the degraded-mode path
#                               # (seeded faults, byte-verified sweep + run)
#   OVERLAP=1 scripts/check.sh  # additionally re-run the test suite with
#                               # round pipelining forced on plus a verified
#                               # 16384-rank sweep under --overlap on
#
# fmt/clippy are skipped with a warning when the components are not
# installed (the offline image ships a bare toolchain).  Set
# REQUIRE_LINT=1 (CI does) to turn those skips into hard failures so a
# runner that silently lost its components cannot green-light unlinted
# code.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
test_log="$(mktemp)"
trap 'rm -f "$test_log"' EXIT
cargo test -q 2>&1 | tee "$test_log"
# No silently-skipped tests: the only sanctioned skips are the xla-gated
# tests, which print "[skip] ..." and still PASS.  A nonzero `ignored`
# count means a test dropped out of the suite (e.g. a rotting read-path
# test) without anyone noticing — fail loudly instead.
if grep -E '(^|[^0-9])[1-9][0-9]* ignored' "$test_log" >/dev/null; then
    echo "check.sh: FAIL — ignored tests detected; only xla-gated [skip] passes may skip" >&2
    exit 1
fi

echo "== tier-1: cargo test -q (TAMIO_THREADS=1, serial pool) =="
# The worker pool must be bit-identical at any width.  The in-process
# determinism matrix (tests/runtime_determinism.rs) covers widths 1/2/3
# via overrides; this leg pins the *global* pool's serial path — every
# test that exercises the default pool re-runs with a width-1 pool.
TAMIO_THREADS=1 cargo test -q

# --features simd needs nightly `portable_simd`.  Probe by compiling a
# snippet that uses the exact APIs the kernels use (u64x8, simd_lt,
# simd_ne, to_bitmask via std::simd::prelude) so toolchain API churn
# skips the leg instead of failing the gate mid-build.  A clean
# "unsupported" probe skips with a notice (the scalar fallback is
# bit-identical and already tested above); under REQUIRE_LINT=1 the
# probe itself erroring in an unexpected way is a hard failure.
simd_probe_dir="$(mktemp -d)"
trap 'rm -f "$test_log"; rm -rf "$simd_probe_dir"' EXIT
cat > "$simd_probe_dir/probe.rs" <<'EOF'
#![feature(portable_simd)]
use std::simd::prelude::*;
fn main() {
    let a = u64x8::from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
    let b = u64x8::splat(5);
    let lt = a.simd_lt(b).to_bitmask().count_ones();
    let ne = a.simd_ne(b).to_bitmask();
    assert_eq!((lt, ne & 0x10), (4, 0));
}
EOF
if probe_out="$(rustc --edition 2021 "$simd_probe_dir/probe.rs" \
        -o "$simd_probe_dir/probe" 2>&1)"; then
    echo "== tier-1: cargo build/test --features simd =="
    cargo build --release --features simd
    cargo test -q --features simd
elif echo "$probe_out" | grep -qE 'portable_simd|feature.*(nightly|stable)|#!\[feature\]' ; then
    echo "notice: toolchain lacks portable_simd; skipping --features simd leg" >&2
elif [ "${REQUIRE_LINT:-0}" = "1" ]; then
    echo "check.sh: FAIL — REQUIRE_LINT=1 and the simd probe failed unexpectedly:" >&2
    echo "$probe_out" >&2
    exit 1
else
    echo "warn: simd probe failed unexpectedly; skipping --features simd leg" >&2
    echo "$probe_out" >&2
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
elif [ "${REQUIRE_LINT:-0}" = "1" ]; then
    echo "check.sh: FAIL — REQUIRE_LINT=1 but rustfmt is not installed" >&2
    exit 1
else
    echo "warn: rustfmt not installed; skipping cargo fmt --check" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
elif [ "${REQUIRE_LINT:-0}" = "1" ]; then
    echo "check.sh: FAIL — REQUIRE_LINT=1 but clippy is not installed" >&2
    exit 1
else
    echo "warn: clippy not installed; skipping" >&2
fi

# Benches are harness = false and excluded from `cargo test`; compile
# them unconditionally so bench-only breakage is caught in tier-1 even
# when BENCH=1 is not set.  Every ablation bench is named explicitly so
# a target-list regression in Cargo.toml cannot silently drop one.
echo "== cargo bench --no-run (bench compile gate) =="
cargo bench --no-run
cargo bench --no-run --bench ablation_depth
cargo bench --no-run --bench ablation_autotune
cargo bench --no-run --bench ablation_faults
cargo bench --no-run --bench ablation_issend
cargo bench --no-run --bench ablation_placement
cargo bench --no-run --bench ablation_overlap

if [ "${BENCH:-0}" = "1" ]; then
    echo "== hot-path bench (writes BENCH_hotpath.json) =="
    cargo bench --bench hotpath
fi

if [ "${SCALE:-0}" = "1" ]; then
    # The ROADMAP scale-sweep item: a small sweep at 16384 ranks on 256
    # nodes, both directions.  E3SM-G at scale 1024 keeps it ~170k
    # requests / ~89 MiB.  Write bars verify by vectored read-back
    # (--verify), read bars always verify the gathered bytes; any
    # mismatch fails the sweep (nonzero exit) and therefore this gate.
    echo "== SCALE=1: 16384-rank / 256-node sweep smoke (both directions) =="
    cargo run --release --bin tamio -- sweep \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --pl 256 --direction both --verify
    # Depth-2 aggregation tree at the same point on the hierarchical
    # topology (4 sockets/node, 16 nodes/switch): write bars verify by
    # vectored read-back, read bars always verify the gathered bytes.
    echo "== SCALE=1: depth-2 tree at 16384 ranks (both directions) =="
    cargo run --release --bin tamio -- run \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --sockets_per_node 4 --nodes_per_switch 16 \
        --algorithm tree:socket=4,node=2 --direction both --verify
fi

if [ "${OVERLAP:-0}" = "1" ]; then
    # Round-pipelining smoke: the whole suite again with the double-
    # buffered round loop forced on via config default override is not
    # possible (overlap defaults off by design), so the determinism
    # matrix in tests/runtime_determinism.rs carries the suite-level
    # coverage; this leg drives the binary end-to-end at the paper's
    # 16384-rank point with --overlap on.  Write bars verify by vectored
    # read-back, read bars always verify the gathered bytes — pipelined
    # output must be bit-identical to serial, so any mismatch fails the
    # gate.
    echo "== OVERLAP=1: pipelined test-suite leg (overlap determinism matrix) =="
    cargo test -q --test runtime_determinism
    echo "== OVERLAP=1: 16384-rank sweep smoke with --overlap on =="
    cargo run --release --bin tamio -- sweep \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --pl 256 --direction both --verify --overlap on
    # Issend bounds the achievable overlap; isend must also round-trip.
    echo "== OVERLAP=1: isend variant under --overlap on =="
    cargo run --release --bin tamio -- run \
        --nodes 256 --ppn 64 --workload e3sm-g --scale 1024 \
        --algorithm tam:256 --send_mode isend --direction both \
        --verify --overlap on
fi

if [ "${FAULTS:-0}" = "1" ]; then
    # Degraded-mode smoke: seeded fault schedule (transient OST failure,
    # half-rate OST range, aggregator dropout) at a small scale.  The
    # sweep charts the cumulative degradation curve; write bars verify by
    # vectored read-back (--verify), read bars always verify the gathered
    # bytes — any mismatch or unabsorbed fault fails the gate.
    echo "== FAULTS=1: degradation-curve sweep (both directions) =="
    cargo run --release --bin tamio -- sweep \
        --nodes 2 --ppn 8 --sockets_per_node 2 --workload strided \
        --algorithm tam:4 --direction both --verify \
        --faults "ost_fail=0@transient:2,ost_slow=0.5x:0-1,agg_drop=?@level:0" \
        --fault-seed 42 --max-retries 6
    # Depth-2 tree with a mid-tree aggregator dropout repaired in place.
    echo "== FAULTS=1: depth-2 tree under aggregator dropout =="
    cargo run --release --bin tamio -- run \
        --nodes 2 --ppn 8 --sockets_per_node 2 --workload strided \
        --algorithm tree:socket=2,node=1 --direction both --verify \
        --faults "agg_drop=?@level:1" --fault-seed 42
fi

echo "check.sh: all gates passed"
